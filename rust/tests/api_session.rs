//! Integration: the `osaca::api` analysis-session layer — request
//! builder, composable passes, true batch submission, structured
//! errors.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use osaca::api::{AnalysisRequest, Backend, Engine, OsacaError, Passes};
use osaca::workloads;

fn triad_request(engine_arch: &str) -> AnalysisRequest {
    let w = workloads::find("triad", engine_arch, "-O3").unwrap();
    Engine::request(&w.name())
        .arch(engine_arch)
        .source(w.source)
        .passes(Passes::ANALYTIC)
        .unroll(w.unroll)
}

#[test]
fn batch_of_16_maps_onto_solver_slots() {
    // Acceptance criterion: 16 requests on the CPU backend complete
    // with at most 4 solver batches (direct B=8 slot mapping gives 2).
    let engine = Engine::cpu_only();
    let reqs: Vec<AnalysisRequest> =
        (0..16).map(|i| triad_request(if i % 2 == 0 { "skl" } else { "zen" })).collect();
    let results = engine.analyze_batch(&reqs);
    assert_eq!(results.len(), 16);
    for (i, r) in results.iter().enumerate() {
        let report = r.as_ref().unwrap_or_else(|e| panic!("request {i}: {e}"));
        // Both native triads are load-bound at 2.0 cy/asm-iter.
        let t = report.throughput.as_ref().unwrap();
        assert!((t.cy_per_asm_iter - 2.0).abs() < 0.01, "request {i}: {}", t.cy_per_asm_iter);
        assert!(report.baseline.is_some(), "request {i} lost its baseline");
    }
    let stats = engine.stats();
    assert_eq!(stats.requests.load(Ordering::Relaxed), 16);
    let batches = stats.batches.load(Ordering::Relaxed);
    assert!(batches <= 4, "expected <=4 solver batches for 16 requests, got {batches}");
    assert_eq!(stats.batched_kernels.load(Ordering::Relaxed), 16);
    assert!(stats.avg_batch_size() >= 4.0, "{}", stats.avg_batch_size());
}

#[test]
fn batch_failures_are_per_request() {
    let engine = Engine::cpu_only();
    let good = triad_request("skl");
    let bad_arch = triad_request("skl").arch("cortex-m4");
    let bad_source = Engine::request("broken").arch("skl").source(".L1:\nfrobnicate %xmm0, %xmm1\njne .L1\n");
    let results = engine.analyze_batch(&[good, bad_arch, bad_source]);
    assert!(results[0].is_ok());
    match &results[1] {
        Err(OsacaError::UnknownArch { requested, available }) => {
            assert_eq!(requested, "cortex-m4");
            assert!(available.iter().any(|a| a == "skl"));
        }
        other => panic!("expected UnknownArch, got {other:?}"),
    }
    match &results[2] {
        Err(OsacaError::UnresolvedForm { form, line, arch }) => {
            assert!(form.starts_with("frobnicate"), "{form}");
            assert_eq!(*line, 2);
            assert_eq!(arch, "skl");
        }
        other => panic!("expected UnresolvedForm, got {other:?}"),
    }
}

#[test]
fn unknown_arch_error_message_lists_alternatives() {
    let engine = Engine::cpu_only();
    let err = engine.machine("m1max").unwrap_err();
    let msg = err.to_string();
    for arch in ["hsw", "skl", "zen"] {
        assert!(msg.contains(arch), "`{msg}` should list `{arch}`");
    }
}

#[test]
fn malformed_model_reports_offending_line() {
    let engine = Engine::cpu_only();
    // Line 3 carries an unknown directive.
    let text = "arch bad \"Bad\"\nports P0\nbogus directive here\n";
    match engine.register_model_text(text) {
        Err(OsacaError::MalformedModel { line, message }) => {
            assert_eq!(line, Some(3), "{message}");
            assert!(message.contains("line 3"), "{message}");
        }
        other => panic!("expected MalformedModel, got {other:?}"),
    }
    // A malformed entry reports its line too.
    let text = "arch bad2 \"Bad2\"\nports P0\nentry vaddpd-xmm_xmm_xmm lat=1 tp=1 uops=c@1:P9\n";
    match engine.register_model_text(text) {
        Err(OsacaError::MalformedModel { line, .. }) => assert_eq!(line, Some(3)),
        other => panic!("expected MalformedModel, got {other:?}"),
    }
}

#[test]
fn passes_are_composable_per_request() {
    let engine = Engine::cpu_only();
    let w = workloads::find("pi", "skl", "-O1").unwrap();
    let base = Engine::request(&w.name()).arch("skl").source(w.source);

    let only_tp = engine.analyze(&base.clone().passes(Passes::THROUGHPUT)).unwrap();
    assert!(only_tp.throughput.is_some());
    assert!(only_tp.critpath.is_none());
    assert!(only_tp.baseline.is_none());
    assert!(only_tp.simulation.is_none());

    let tp_cp = engine
        .analyze(&base.clone().passes(Passes::THROUGHPUT | Passes::CRITPATH))
        .unwrap();
    let t = tp_cp.throughput.as_ref().unwrap();
    let c = tp_cp.critpath.as_ref().unwrap();
    assert!((t.cy_per_asm_iter - 4.75).abs() < 0.01);
    // The store-forward chain dominates the throughput bound.
    assert!(c.carried_per_iteration > 8.0);
    assert!(
        (tp_cp.predicted_cy_per_asm_iter().unwrap() - c.carried_per_iteration).abs() < 1e-6
    );
}

#[test]
fn report_renders_text_and_json() {
    let engine = Engine::cpu_only();
    let report = engine.analyze(&triad_request("skl")).unwrap();
    let text = report.to_text();
    assert!(text.contains("Throughput bottleneck"));
    assert!(text.contains("Balanced (IACA-like) baseline"));
    let json = report.to_json();
    assert!(json.contains("\"name\":"));
    assert!(json.contains("\"throughput\":"));
    assert!(json.contains("\"critpath\":"));
    assert!(json.contains("\"baseline\":"));
    assert!(!json.contains("\"simulation\":"));
}

#[test]
fn engine_is_shareable_across_threads() {
    let engine = Arc::new(Engine::cpu_only());
    let mut handles = Vec::new();
    for i in 0..8 {
        let engine = engine.clone();
        handles.push(std::thread::spawn(move || {
            let arch = if i % 2 == 0 { "skl" } else { "zen" };
            let report = engine.analyze(&triad_request(arch)).unwrap();
            report.throughput.unwrap().cy_per_asm_iter
        }));
    }
    for h in handles {
        let cy = h.join().unwrap();
        assert!((cy - 2.0).abs() < 0.01, "{cy}");
    }
    assert_eq!(engine.stats().requests.load(Ordering::Relaxed), 8);
}

#[test]
fn builder_exposes_service_tunables() {
    let engine = Engine::builder()
        .backend(Backend::Cpu)
        .reply_timeout(Duration::from_millis(500))
        .batch_window(Duration::from_micros(50))
        .queue_depth(64)
        .build();
    assert_eq!(engine.coordinator().reply_timeout, Duration::from_millis(500));
    assert_eq!(engine.coordinator().window, Duration::from_micros(50));
    // And the engine still serves requests with those settings.
    assert!(engine.analyze(&triad_request("skl")).is_ok());
}

#[test]
fn legacy_shims_agree_with_engine() {
    use osaca::coordinator::Coordinator;
    let engine = Engine::cpu_only();
    let coord = Coordinator::cpu_only();
    let w = workloads::find("pi", "skl", "-O2").unwrap();
    let legacy = coord.analyze_source(&w.name(), w.source, "skl").unwrap();
    let report = engine
        .analyze(&Engine::request(&w.name()).arch("skl").source(w.source).passes(Passes::ANALYTIC))
        .unwrap();
    let t = report.throughput.as_ref().unwrap();
    let b = report.baseline.as_ref().unwrap();
    assert!((legacy.osaca.cy_per_asm_iter - t.cy_per_asm_iter).abs() < 1e-6);
    assert!((legacy.baseline.cy_per_asm_iter - b.cy_per_asm_iter).abs() < 1e-5);
}
