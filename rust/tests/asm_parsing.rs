//! Integration: parse every shipped workload fixture end-to-end and
//! check structural properties of the extracted kernels.

use osaca::asm::{extract_kernel, parse_file, Line};
use osaca::isa::Operand;
use osaca::workloads;

#[test]
fn every_fixture_parses_line_by_line() {
    for w in workloads::all() {
        let lines = parse_file(w.source).unwrap_or_else(|e| panic!("{}: {e}", w.name()));
        let n_instr = lines.iter().filter(|l| matches!(l, Line::Instruction(_))).count();
        assert!(n_instr >= 5, "{}: only {n_instr} instructions", w.name());
    }
}

#[test]
fn marked_regions_exclude_markers() {
    for w in workloads::all() {
        let k = w.kernel();
        for i in &k.instructions {
            assert_ne!(i.mnemonic, "movl", "{}: marker leaked into kernel: {i}", w.name());
        }
    }
}

#[test]
fn triad_o3_skl_matches_paper_listing() {
    let k = workloads::find("triad", "skl", "-O3").unwrap().kernel();
    let mnemonics: Vec<&str> = k.instructions.iter().map(|i| i.mnemonic.as_str()).collect();
    assert_eq!(
        mnemonics,
        ["vmovapd", "vmovapd", "addl", "vfmadd132pd", "vmovapd", "addq", "cmpl", "ja"]
    );
    // The FMA reads memory with base+index addressing.
    let fma = &k.instructions[3];
    let mem = fma.mem_operand().unwrap();
    assert!(!mem.is_simple());
    assert_eq!(fma.form().to_string(), "vfmadd132pd-mem_ymm_ymm");
}

#[test]
fn pi_o1_has_stack_roundtrip() {
    let k = workloads::find("pi", "skl", "-O1").unwrap().kernel();
    let load = k
        .instructions
        .iter()
        .find(|i| i.is_load() && i.mnemonic == "vaddsd")
        .expect("stack load");
    let store = k.instructions.iter().find(|i| i.is_store()).expect("stack store");
    let lm = load.mem_operand().unwrap();
    let sm = store.mem_operand().unwrap();
    assert_eq!(lm.base.unwrap().name, "rsp");
    assert_eq!(sm.base.unwrap().name, "rsp");
    assert_eq!(lm.displacement, sm.displacement);
}

#[test]
fn operand_roundtrip_display() {
    let k = workloads::find("triad", "zen", "-O3").unwrap().kernel();
    for i in &k.instructions {
        // Display form must re-parse to the same instruction form.
        let text = i.to_string();
        let re = osaca::asm::parse_instruction(&text, i.line).unwrap();
        assert_eq!(re.form(), i.form(), "{text}");
    }
}

#[test]
fn branch_targets_resolve_to_loop_head() {
    for w in workloads::all() {
        let k = w.kernel();
        let last = k.instructions.last().unwrap();
        assert!(last.is_branch(), "{}", w.name());
        match last.operands.first() {
            Some(Operand::Label(l)) => assert_eq!(Some(l), k.loop_label.as_ref()),
            other => panic!("{}: branch operand {other:?}", w.name()),
        }
    }
}
