//! Integration: the opt-in cache hierarchy + LSQ (`sim::mem`).
//!
//! Two invariants matter. **Off**: with no `mem_model` on the request,
//! every Measurement and every prediction is bit-identical to the
//! infinite-L1 seed — the paper-pinned tables cannot drift. **On**: the
//! strided triad's working-set sweep produces the hand-derived ECM
//! numbers (8 lines/iter on skl: 8.0 cy in L2, 40.0 in L3, 76.0 in
//! memory), and a starved LSQ shows up in the counters and the
//! bottleneck label.

use osaca::api::{Engine, OsacaError, Passes};
use osaca::mdb::by_name;
use osaca::sim::{
    analyze_memory, derive_footprint, run_decoded, run_decoded_mem, DecodedKernel, MemModel,
    MemSimPlan, SimConfig,
};
use osaca::workloads;

fn cfg() -> SimConfig {
    SimConfig { iterations: 400, warmup: 100 }
}

/// `run_decoded_mem(.., None)` is `run_decoded`: same cycles, same
/// counters, same port busy — on every ISA the simulator supports.
#[test]
fn off_mode_is_bit_identical_across_isas() {
    for (family, arch, flag) in [
        ("triad", "skl", "-O3"),
        ("triad", "zen", "-O3"),
        ("triad", "tx2", "-O2"),
        ("triad", "rv64", "-O2"),
    ] {
        let w = workloads::find(family, arch, flag).unwrap();
        let m = by_name(arch).unwrap();
        let dk = DecodedKernel::new(&w.kernel(), &m).unwrap();
        let plain = run_decoded(&dk, &m, cfg());
        let off = run_decoded_mem(&dk, &m, cfg(), None);
        assert_eq!(plain.total_cycles, off.total_cycles, "{arch}");
        assert_eq!(plain.window_cycles, off.window_cycles, "{arch}");
        assert_eq!(plain.counters, off.counters, "{arch}");
        assert_eq!(plain.port_busy, off.port_busy, "{arch}");
        assert_eq!(plain.cycles_per_iteration, off.cycles_per_iteration, "{arch}");
        // Off mode can never touch the memory-model counters.
        assert_eq!(off.counters.lsq_stall_cycles, 0, "{arch}");
        assert_eq!(off.counters.cache_miss_loads, 0, "{arch}");
    }
}

fn strided_report(engine: &Engine, spec: Option<&str>) -> osaca::api::AnalysisReport {
    let w = workloads::find("triad-strided", "any", "-O3").unwrap();
    let mut req = Engine::request(&w.name())
        .arch("skl")
        .source(w.source)
        .passes(Passes::THROUGHPUT)
        .unroll(w.unroll);
    if let Some(s) = spec {
        req = req.mem_model(s);
    }
    engine.analyze(&req).unwrap_or_else(|e| panic!("{e}"))
}

/// End to end through the Engine: the strided triad is port-bound at
/// 2.0 cy under infinite L1 and whenever L1-resident, then memory-bound
/// at the hand-derived ECM values as the working set walks the skl
/// hierarchy (L2 8.0, L3 40.0, DRAM 76.0 cy / asm iteration).
#[test]
fn strided_triad_walks_the_hierarchy() {
    let engine = Engine::cpu_only();
    let base = strided_report(&engine, None);
    let w0 = base.prediction().winner().unwrap().cy_per_asm_iter;
    assert!((w0 - 2.0).abs() < 1e-6, "{w0}");
    assert!(base.memory.is_none());

    for (spec, cy, kind, level) in [
        ("ws=16K", 2.0f32, "port_pressure", "l1"),
        ("ws=64K", 8.0, "memory", "l2"),
        ("ws=4M", 40.0, "memory", "l3"),
        ("ws=64M", 76.0, "memory", "mem"),
    ] {
        let r = strided_report(&engine, Some(spec));
        let p = r.prediction();
        let win = p.winner().unwrap();
        assert!((win.cy_per_asm_iter - cy).abs() < 1e-6, "{spec}: {}", win.cy_per_asm_iter);
        assert_eq!(win.kind.name(), kind, "{spec}");
        let mem = r.memory.as_ref().expect(spec);
        assert_eq!(mem.level, level, "{spec}");
        // The footprint derivation sees all four 128 B/iter streams.
        assert_eq!(mem.streams, 4, "{spec}");
        assert_eq!(mem.bytes_per_iter, 512, "{spec}");
        assert!((mem.lines_per_iter - 8.0).abs() < 1e-6, "{spec}");
    }
}

/// A starved LSQ (4 entries = one iteration's Load/StoreAgu µ-ops)
/// under an L3-resident working set: the stall shows up in the new
/// counters, slows the simulated iteration down, and wins the
/// bottleneck label.
#[test]
fn lsq_starvation_stalls_and_is_attributed() {
    let w = workloads::find("triad-strided", "any", "-O3").unwrap();
    let m = by_name("skl").unwrap();
    let k = w.kernel();
    let dk = DecodedKernel::new(&k, &m).unwrap();
    let off = run_decoded(&dk, &m, cfg());

    let model = MemModel::build(&m, "ws=4M,lsq=4").unwrap();
    let fp = derive_footprint(&k, &dk.iter, model.line_bytes());
    let analysis = analyze_memory(&model, &fp, cfg().iterations as u64);
    assert_eq!(analysis.level, "l3");
    assert_eq!(analysis.level_latency_cy, 44);
    let plan = MemSimPlan::new(&model, &analysis, &fp);
    assert_eq!(plan.miss_latency_cy, 40);

    let on = run_decoded_mem(&dk, &m, cfg(), Some(&plan));
    assert!(on.counters.lsq_stall_cycles > 0);
    assert!(on.counters.cache_miss_loads > 0);
    assert!(
        on.cycles_per_iteration > off.cycles_per_iteration,
        "{} vs {}",
        on.cycles_per_iteration,
        off.cycles_per_iteration
    );
    assert_eq!(on.bottleneck_resource(&m), "load/store queue");
}

/// A malformed spec is a structured `BadMemModel`, not a panic and not
/// a silent fallback to infinite L1.
#[test]
fn bad_spec_is_a_structured_error() {
    let engine = Engine::cpu_only();
    let w = workloads::find("triad-strided", "any", "-O3").unwrap();
    for bad in ["l1=bogus:4", "lsq=0", "l9=1M:5,l1=32K:90", "nonsense"] {
        let req = Engine::request(&w.name())
            .arch("skl")
            .source(w.source)
            .passes(Passes::THROUGHPUT)
            .unroll(w.unroll)
            .mem_model(bad);
        match engine.analyze(&req) {
            Err(OsacaError::BadMemModel { message }) => {
                assert!(!message.is_empty(), "{bad}");
            }
            other => panic!("{bad}: expected BadMemModel, got {other:?}"),
        }
    }
}
