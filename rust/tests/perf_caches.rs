//! Tests for the zero-realloc pipeline caches (PR 2):
//!
//! * `FormIndex`: repeated `analyze`/`simulate`/`encode` of the same
//!   kernel performs zero fresh form resolutions after the first pass;
//! * `DecodedKernel` reuse produces bit-identical `Measurement`s to
//!   fresh decodes on every workload and both architectures;
//! * the pooled `Engine::analyze_batch` returns results in request
//!   order with per-slot errors preserved.

use osaca::analyzer::{analyze, critical_path};
use osaca::api::{Engine, OsacaError, Passes};
use osaca::baseline::encode;
use osaca::mdb;
use osaca::sim::{run_decoded, simulate, DecodedKernel, SimConfig};
use osaca::workloads;

#[test]
fn repeated_analysis_performs_no_fresh_resolutions() {
    // A private model instance => a private miss counter, immune to
    // other tests in this binary warming the shared registry model.
    let m = mdb::skylake();
    let cfg = SimConfig { iterations: 60, warmup: 15 };
    for w in workloads::all() {
        let k = w.kernel();
        // First pass over each entry point warms the caches.
        analyze(&k, &m).unwrap();
        simulate(&k, &m, cfg).unwrap();
        encode(&k, &m).unwrap();
        critical_path(&k, &m).unwrap();
        let misses = m.resolution_miss_count();
        // Every further pass must be served entirely from the cache.
        for _ in 0..3 {
            analyze(&k, &m).unwrap();
            simulate(&k, &m, cfg).unwrap();
            encode(&k, &m).unwrap();
            critical_path(&k, &m).unwrap();
        }
        assert_eq!(
            m.resolution_miss_count(),
            misses,
            "{}: repeated analysis re-synthesized a form",
            w.name()
        );
    }
    // The process-wide counter exists and has seen this work.
    assert!(mdb::resolution_miss_count() >= m.resolution_miss_count());
}

#[test]
fn decoded_kernel_reuse_is_bit_identical() {
    let cfg = SimConfig { iterations: 150, warmup: 40 };
    for arch in ["skl", "zen"] {
        let m = mdb::by_name_shared(arch).unwrap();
        for w in workloads::all() {
            let k = w.kernel();
            let fresh = simulate(&k, &m, cfg).unwrap();
            let dk = DecodedKernel::new(&k, &m).unwrap();
            for round in 0..3 {
                let reused = run_decoded(&dk, &m, cfg);
                let tag = format!("{}/{arch} round {round}", w.name());
                assert_eq!(fresh.total_cycles, reused.total_cycles, "{tag}");
                assert_eq!(fresh.window_cycles, reused.window_cycles, "{tag}");
                assert_eq!(fresh.iterations, reused.iterations, "{tag}");
                assert_eq!(fresh.counters, reused.counters, "{tag}");
                assert_eq!(fresh.port_busy, reused.port_busy, "{tag}");
                assert_eq!(
                    fresh.cycles_per_iteration.to_bits(),
                    reused.cycles_per_iteration.to_bits(),
                    "{tag}"
                );
            }
        }
    }
}

#[test]
fn decoded_kernel_clone_shares_template() {
    let m = mdb::by_name_shared("skl").unwrap();
    let k = workloads::find("pi", "skl", "-O3").unwrap().kernel();
    let dk = DecodedKernel::new(&k, &m).unwrap();
    let dk2 = dk.clone();
    assert!(std::sync::Arc::ptr_eq(&dk.iter, &dk2.iter));
    assert_eq!(dk.total_slots(), dk2.total_slots());
}

#[test]
fn pooled_batch_preserves_order_and_per_slot_errors() {
    let engine = Engine::cpu_only();
    let ws = workloads::all();
    let good_src = ws[0].source;
    let mut reqs = Vec::new();
    for i in 0..24usize {
        let req = if i % 5 == 3 {
            // Unresolvable form: fails pre-validation in its slot.
            Engine::request(&format!("bad-{i}"))
                .arch("skl")
                .source("\n.L1:\nfrobnicate %xmm0, %xmm1\njne .L1\n")
        } else if i % 7 == 4 {
            // Unknown architecture: fails model lookup in its slot.
            Engine::request(&format!("noarch-{i}")).arch("m1max").source(good_src)
        } else {
            let w = ws[i % ws.len()];
            Engine::request(&format!("req-{i}"))
                .arch(if i % 2 == 0 { "skl" } else { "zen" })
                .source(w.source)
                .passes(Passes::ANALYTIC)
                .unroll(w.unroll)
        };
        reqs.push(req);
    }
    let results = engine.analyze_batch(&reqs);
    assert_eq!(results.len(), reqs.len());
    for (i, r) in results.iter().enumerate() {
        if i % 5 == 3 {
            match r {
                Err(OsacaError::UnresolvedForm { form, arch, .. }) => {
                    assert!(form.contains("frobnicate"), "slot {i}: {form}");
                    assert_eq!(arch, "skl");
                }
                other => panic!("slot {i}: expected UnresolvedForm, got {other:?}"),
            }
        } else if i % 7 == 4 {
            match r {
                Err(OsacaError::UnknownArch { requested, .. }) => {
                    assert_eq!(requested, "m1max", "slot {i}");
                }
                other => panic!("slot {i}: expected UnknownArch, got {other:?}"),
            }
        } else {
            let rep = r.as_ref().unwrap_or_else(|e| panic!("slot {i}: {e}"));
            // Order is preserved: the report carries its request's name.
            assert_eq!(rep.name, format!("req-{i}"));
            assert!(rep.throughput.is_some(), "slot {i}");
            assert!(rep.critpath.is_some(), "slot {i}");
            assert!(rep.baseline.is_some(), "slot {i}");
        }
    }
}

#[test]
fn pooled_batch_matches_serial_analyze() {
    // The worker pool must not change any numbers: batch results equal
    // one-at-a-time analyze() results.
    let engine = Engine::cpu_only();
    let reqs: Vec<_> = workloads::all()
        .iter()
        .map(|w| {
            Engine::request(&w.name())
                .arch("skl")
                .source(w.source)
                .passes(Passes::THROUGHPUT | Passes::CRITPATH)
                .unroll(w.unroll)
        })
        .collect();
    let batch = engine.analyze_batch(&reqs);
    for (req, b) in reqs.iter().zip(batch) {
        let serial = engine.analyze(req).unwrap();
        let b = b.unwrap();
        let (st, bt) = (serial.throughput.unwrap(), b.throughput.unwrap());
        assert_eq!(st.cy_per_asm_iter.to_bits(), bt.cy_per_asm_iter.to_bits(), "{}", req.name);
        assert_eq!(st.bottleneck_port, bt.bottleneck_port, "{}", req.name);
        let (sc, bc) = (serial.critpath.unwrap(), b.critpath.unwrap());
        assert_eq!(
            sc.carried_per_iteration.to_bits(),
            bc.carried_per_iteration.to_bits(),
            "{}",
            req.name
        );
    }
}
