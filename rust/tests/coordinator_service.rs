//! Integration: the batching coordinator under concurrent load.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use osaca::coordinator::Coordinator;
use osaca::mdb;
use osaca::workloads;

#[test]
fn coordinator_serves_all_workloads_on_both_arches() {
    let coord = Coordinator::auto();
    for arch in ["skl", "zen"] {
        let machine = mdb::by_name(arch).unwrap();
        for w in workloads::all() {
            let r = coord.analyze_kernel(&w.kernel(), &machine).unwrap();
            assert!(r.osaca.cy_per_asm_iter > 0.0, "{} {}", arch, w.name());
            assert!(
                r.baseline.cy_per_asm_iter <= r.osaca.cy_per_asm_iter + 0.3,
                "{} {}: baseline {} osaca {}",
                arch,
                w.name(),
                r.baseline.cy_per_asm_iter,
                r.osaca.cy_per_asm_iter
            );
        }
    }
}

#[test]
fn heavy_concurrency_is_correct_and_batches() {
    let coord = Arc::new(Coordinator::auto());
    let n = 64;
    let mut handles = Vec::new();
    for i in 0..n {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            let w = workloads::find("triad", "skl", "-O3").unwrap();
            let m = mdb::skylake();
            let r = coord.analyze_kernel(&w.kernel(), &m).unwrap();
            // Every request gets the same right answer regardless of
            // which batch slot it landed in.
            assert!((r.osaca.cy_per_asm_iter - 2.0).abs() < 0.01, "req {i}");
            r.baseline.cy_per_asm_iter
        }));
    }
    let mut preds = Vec::new();
    for h in handles {
        preds.push(h.join().unwrap());
    }
    let first = preds[0];
    assert!(preds.iter().all(|p| (p - first).abs() < 1e-5));
    assert_eq!(coord.stats.requests.load(Ordering::Relaxed), n as u64);
    let batches = coord.stats.batches.load(Ordering::Relaxed);
    assert!(batches >= 1 && batches <= n as u64);
}

#[test]
fn mixed_arch_batching_keeps_results_separate() {
    let coord = Arc::new(Coordinator::auto());
    let mut handles = Vec::new();
    for i in 0..32 {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || -> (usize, f32) {
            let w = workloads::find("triad", "skl", "-O3").unwrap();
            let arch = if i % 2 == 0 { "skl" } else { "zen" };
            let m = mdb::by_name(arch).unwrap();
            let r = coord.analyze_kernel(&w.kernel(), &m).unwrap();
            (i, r.osaca.cy_per_asm_iter)
        }));
    }
    for h in handles {
        let (i, cy) = h.join().unwrap();
        let want = if i % 2 == 0 { 2.0 } else { 4.0 };
        assert!((cy - want).abs() < 0.01, "req {i}: {cy}");
    }
}

#[test]
fn analyze_source_end_to_end() {
    let coord = Coordinator::cpu_only();
    let w = workloads::find("pi", "skl", "-O1").unwrap();
    let r = coord.analyze_source(&w.name(), w.source, "skl").unwrap();
    assert!((r.osaca.cy_per_asm_iter - 4.75).abs() < 0.01);
    // Critical path flags the store-forwarding chain.
    assert!(r.critpath.carried_per_iteration > 8.0, "{:?}", r.critpath);
}
