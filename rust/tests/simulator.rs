//! Integration: the simulator substrate reproduces the paper's
//! *measured* columns (Tables III and V) and the §III-B counter story.

use osaca::mdb::{by_name, skylake, zen};
use osaca::sim::{simulate, SimConfig};
use osaca::workloads;

fn cfg() -> SimConfig {
    SimConfig { iterations: 600, warmup: 150 }
}

fn measure(family: &str, arch: &str, flag: &str) -> osaca::sim::Measurement {
    let w = workloads::find(family, arch, flag).unwrap();
    let m = by_name(arch).unwrap();
    simulate(&w.kernel(), &m, cfg()).unwrap()
}

/// Table III row 12: triad -O3 on Skylake: ~0.5 cy/it (paper: 0.53).
#[test]
fn triad_o3_skl_native() {
    let m = measure("triad", "skl", "-O3");
    let cy_it = m.cy_per_source_it(4);
    assert!((0.48..0.58).contains(&cy_it), "{cy_it}");
}

/// Table III row 9: SKL AVX2 code on Zen: ~1.0 cy/it (paper: 1.01),
/// i.e. 2x the native Skylake result — the AVX-splitting effect.
#[test]
fn triad_o3_skl_code_on_zen() {
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let m = simulate(&w.kernel(), &zen(), cfg()).unwrap();
    let cy_it = m.cy_per_source_it(4);
    assert!((0.95..1.15).contains(&cy_it), "{cy_it}");
}

/// Table III row 3: Zen native -O3: ~1.0 cy/it (paper: 1.02).
#[test]
fn triad_o3_zen_native() {
    let m = measure("triad", "zen", "-O3");
    let cy_it = m.cy_per_source_it(2);
    assert!((0.95..1.15).contains(&cy_it), "{cy_it}");
}

/// Table III row 6: Zen xmm code on Skylake: ~1.0 cy/it (paper: 1.03).
#[test]
fn triad_o3_zen_code_on_skl() {
    let w = workloads::find("triad", "zen", "-O3").unwrap();
    let m = simulate(&w.kernel(), &skylake(), cfg()).unwrap();
    let cy_it = m.cy_per_source_it(2);
    assert!((0.95..1.15).contains(&cy_it), "{cy_it}");
}

/// Table III scalar rows: ~2 cy/it on both machines.
#[test]
fn triad_scalar_rows() {
    for arch in ["skl", "zen"] {
        for flag in ["-O1", "-O2"] {
            let m = measure("triad", arch, flag);
            let cy_it = m.cy_per_source_it(1);
            assert!((1.9..2.3).contains(&cy_it), "{arch} {flag}: {cy_it}");
        }
    }
}

/// Table V measured column, Skylake: 9.02 / 4.00 / 2.06.
#[test]
fn pi_skl_measured() {
    let o1 = measure("pi", "skl", "-O1").cy_per_source_it(1);
    assert!((8.3..9.7).contains(&o1), "{o1}");
    let o2 = measure("pi", "skl", "-O2").cy_per_source_it(1);
    assert!((3.8..4.3).contains(&o2), "{o2}");
    let o3 = measure("pi", "skl", "-O3").cy_per_source_it(8);
    assert!((1.9..2.2).contains(&o3), "{o3}");
}

/// Table V measured column, Zen: 11.48 / 4.96 / 2.44.
#[test]
fn pi_zen_measured() {
    let o1 = measure("pi", "zen", "-O1").cy_per_source_it(1);
    assert!((10.2..12.3).contains(&o1), "{o1}");
    let o2 = measure("pi", "zen", "-O2").cy_per_source_it(1);
    assert!((4.5..5.4).contains(&o2), "{o2}");
    let o3 = measure("pi", "zen", "-O3").cy_per_source_it(8);
    assert!((2.2..2.8).contains(&o3), "{o3}");
}

/// §III-B: the -O1 π kernel shows far more issue-stall cycles than
/// -O2 on Skylake (paper: 17x); forwarding is the cause. On Zen the
/// 5-cycle divider period leaves ports idle at -O2 as well, so our
/// substrate shows the effect in the *forwarded-loads* counter rather
/// than a large issue-stall factor (the paper reads a different event,
/// the retire-token stall, there).
#[test]
fn pi_o1_stall_counters() {
    for arch in ["skl", "zen"] {
        let o1 = measure("pi", arch, "-O1");
        let o2 = measure("pi", arch, "-O2");
        assert!(o1.counters.forwarded_loads > 0, "{arch}");
        assert_eq!(o2.counters.forwarded_loads, 0, "{arch}");
        let f1 = o1.counters.issue_stall_cycles as f64 / o1.window_cycles as f64;
        let f2 = o2.counters.issue_stall_cycles as f64 / o2.window_cycles as f64;
        if arch == "skl" {
            assert!(f1 > 3.0 * f2.max(0.02), "{arch}: {f1} vs {f2}");
        } else {
            assert!(f1 > 0.8 * f2, "{arch}: {f1} vs {f2}");
        }
    }
}

/// Extra workloads behave per their design notes.
#[test]
fn extra_workloads_bottlenecks() {
    // sum reduction: latency-bound at FP-add latency (4 SKL / 3 Zen).
    let skl = simulate(
        &workloads::find("sum", "skl", "-O2").unwrap().kernel(),
        &skylake(),
        cfg(),
    )
    .unwrap();
    assert!((3.8..4.4).contains(&skl.cycles_per_iteration), "{}", skl.cycles_per_iteration);
    let z = simulate(
        &workloads::find("sum", "zen", "-O2").unwrap().kernel(),
        &zen(),
        cfg(),
    )
    .unwrap();
    assert!((2.8..3.4).contains(&z.cycles_per_iteration), "{}", z.cycles_per_iteration);

    // daxpy in-place: no false cross-iteration forwarding.
    let d = simulate(
        &workloads::find("daxpy", "skl", "-O3").unwrap().kernel(),
        &skylake(),
        cfg(),
    )
    .unwrap();
    assert_eq!(d.counters.forwarded_loads, 0);
    assert!(d.cycles_per_iteration < 3.0, "{}", d.cycles_per_iteration);
}

/// Legacy-SSE triad (2-operand forms): same 2 cy/asm-iter load bound on
/// both machines, and the analyzer agrees (exercises the non-VEX DB
/// entries and the mov-family dest semantics).
#[test]
fn sse_triad_two_cycles() {
    use osaca::analyzer::analyze;
    let w = workloads::find("triad-sse", "skl", "-O3").unwrap();
    for m in [skylake(), zen()] {
        let a = analyze(&w.kernel(), &m).unwrap();
        assert!((a.cy_per_asm_iter - 2.0).abs() < 0.01, "{}: {}", m.name, a.cy_per_asm_iter);
        let meas = simulate(&w.kernel(), &m, cfg()).unwrap();
        assert!(
            (meas.cycles_per_iteration - 2.0).abs() < 0.25,
            "{}: {}",
            m.name,
            meas.cycles_per_iteration
        );
    }
}

/// Determinism: same kernel, same config, same result.
#[test]
fn simulation_is_deterministic() {
    let a = measure("pi", "skl", "-O2");
    let b = measure("pi", "skl", "-O2");
    assert_eq!(a.cycles_per_iteration, b.cycles_per_iteration);
    assert_eq!(a.counters, b.counters);
}
