//! Integration: the §II model-construction methodology end-to-end —
//! benchmark on the simulator, infer entries, compare with the shipped
//! databases.

use osaca::builder::{default_probes, infer_entry, validate_model};
use osaca::ibench::{measure_latency, measure_throughput, BenchSpec};
use osaca::isa::InstructionForm;
use osaca::mdb::{skylake, zen, PortMask, UopKind};

/// §II-A: vaddpd latency 4 cy on SKL / 3 cy on Zen; rTP 0.5 on both.
#[test]
fn section2a_vaddpd() {
    let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
    assert!((measure_latency(&spec, &skylake()).unwrap() - 4.0).abs() < 0.2);
    assert!((measure_latency(&spec, &zen()).unwrap() - 3.0).abs() < 0.2);
    for m in [skylake(), zen()] {
        assert!((measure_throughput(&spec, &m).unwrap() - 0.5).abs() < 0.1, "{}", m.name);
    }
}

/// §II-C on Zen: FMA-mem latency 5, rTP 0.5, ports FP0/FP1 + loads.
#[test]
fn section2c_fma_zen() {
    let z = zen();
    let probes = default_probes(&z);
    let form = InstructionForm::parse("vfmadd132pd-mem_xmm_xmm");
    let inf = infer_entry(&form, &z, &probes).unwrap();
    assert!((inf.measured_latency - 5.0).abs() < 0.3, "{}", inf.measured_latency);
    assert!((inf.measured_rtp - 0.5).abs() < 0.1, "{}", inf.measured_rtp);
    let c = inf.entry.uops.iter().find(|u| u.kind == UopKind::Compute).unwrap();
    assert_eq!(c.ports, PortMask::from_ports(&[0, 1]), "FP0|FP1");
    assert!(inf.entry.uops.iter().any(|u| u.kind == UopKind::Load));
}

/// §II-C on Skylake: same benchmarks, FMA on P0/P1.
#[test]
fn section2c_fma_skl() {
    let m = skylake();
    let probes = default_probes(&m);
    let form = InstructionForm::parse("vfmadd132pd-mem_xmm_xmm");
    let inf = infer_entry(&form, &m, &probes).unwrap();
    assert!((inf.measured_latency - 4.0).abs() < 0.3, "{}", inf.measured_latency);
    assert!((inf.measured_rtp - 0.5).abs() < 0.1, "{}", inf.measured_rtp);
    let c = inf.entry.uops.iter().find(|u| u.kind == UopKind::Compute).unwrap();
    assert!(c.ports.contains(0) && c.ports.contains(1), "{:?}", c.ports);
}

/// Divider throughput measured through the DV pipe on both machines.
/// Note Zen measures ~5 cy (the sim_divider_scale imperfection the
/// §III-B discussion attributes to the real machine) while the DB says
/// 4 — the same model-vs-hardware gap the paper reports.
#[test]
fn divider_inference() {
    let spec = BenchSpec::parse("vdivsd-xmm_xmm_xmm");
    let skl_tp = measure_throughput(&spec, &skylake()).unwrap();
    assert!((skl_tp - 4.0).abs() < 0.3, "{skl_tp}");
    let zen_tp = measure_throughput(&spec, &zen()).unwrap();
    assert!((zen_tp - 5.0).abs() < 0.4, "{zen_tp}");
}

/// Re-derive a representative slice of both databases and verify.
#[test]
fn validate_shipped_models() {
    let forms: Vec<InstructionForm> = [
        "vaddpd-xmm_xmm_xmm",
        "vmulpd-xmm_xmm_xmm",
        "vfmadd132pd-xmm_xmm_xmm",
        "vfmadd132pd-mem_xmm_xmm",
        "vpaddd-xmm_xmm_xmm",
        "add-imm_r",
        // NOTE: pure-load forms (vmovaps-mem_xmm) are excluded: their
        // latency needs pointer-chasing benchmarks (the dest cannot feed
        // a fixed address), a limitation shared with the paper's ibench.
        "vaddsd-mem_xmm_xmm",
    ]
    .iter()
    .map(|s| InstructionForm::parse(s))
    .collect();
    for machine in [skylake(), zen()] {
        let rows = validate_model(&machine, &forms).unwrap();
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.ok(), "{}: {r:?}", machine.name);
        }
    }
}

/// Latency benchmarks agree with the DB latency field for FP math.
#[test]
fn latency_sweep_against_db() {
    for machine in [skylake(), zen()] {
        for f in ["vaddpd-xmm_xmm_xmm", "vmulpd-xmm_xmm_xmm", "vfmadd132pd-xmm_xmm_xmm"] {
            let form = InstructionForm::parse(f);
            let db = machine.entries.get(&form).unwrap().latency as f64;
            let meas = measure_latency(&BenchSpec { form }, &machine).unwrap();
            assert!((meas - db).abs() < 0.3, "{} {f}: {meas} vs {db}", machine.name);
        }
    }
}
