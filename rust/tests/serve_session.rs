//! ISSUE-6: the persistent sharded analysis service, exercised over a
//! real TCP socket.
//!
//! * Concurrent clients round-trip schema-versioned frames whose
//!   embedded reports byte-match the emitter golden files.
//! * The cross-request memo is observable on the wire: `memo_hit`
//!   flips on the second identical request and the `stats` counters
//!   pin hit/miss/analysis accounting exactly.
//! * A saturated 1-slot shard queue answers `overloaded` instead of
//!   blocking, and the same connection succeeds on retry.
//! * Malformed frames produce structured errors and the connection
//!   survives them.
//! * A wire `shutdown` acknowledges with `bye` and the server drains
//!   cleanly.
//! * `--models-dir` models resolve at bind time, and the
//!   `reload_models` op picks up `.mdb` files dropped in later without
//!   a restart (counted by `model_reloads` in `stats`).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::Duration;

use osaca::api::Backend;
use osaca::report::emit::json_string;
use osaca::serve::json::{self, JsonValue};
use osaca::serve::{ServeConfig, Server};
use osaca::workloads;

/// A line-oriented test client over one persistent connection.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { stream, reader }
    }

    fn send(&mut self, frame: &str) {
        self.stream.write_all(frame.as_bytes()).expect("send frame");
        self.stream.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read frame");
        assert!(n > 0, "server closed the connection unexpectedly");
        line.trim_end().to_string()
    }

    fn round_trip(&mut self, frame: &str) -> String {
        self.send(frame);
        self.recv()
    }
}

fn serve(cfg: ServeConfig) -> Server {
    Server::bind(cfg).expect("bind server")
}

fn cpu_config() -> ServeConfig {
    ServeConfig { addr: "127.0.0.1:0".to_string(), backend: Backend::Cpu, ..Default::default() }
}

/// The wire request whose embedded report must byte-match
/// `golden/skl_triad.json`.
fn skl_request() -> String {
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    format!(
        "{{\"op\":\"analyze\",\"name\":\"{}\",\"arch\":\"skl\",\"source\":{},\
         \"passes\":[\"throughput\"],\"unroll\":{},\"format\":\"json\"}}",
        w.name(),
        json_string(w.source),
        w.unroll
    )
}

/// The wire request whose embedded report must byte-match
/// `golden/rv64_triad.json`.
fn rv64_request() -> String {
    let w = workloads::find("triad", "rv64", "-O2").unwrap();
    format!(
        "{{\"op\":\"analyze\",\"name\":\"{}\",\"arch\":\"rv64\",\"source\":{},\
         \"passes\":[\"throughput\",\"critpath\"],\"frontend_bound\":true,\
         \"unroll\":{},\"format\":\"json\"}}",
        w.name(),
        json_string(w.source),
        w.unroll
    )
}

/// Slice the raw report object out of an ok frame; `report` is the last
/// key by contract so the payload runs to the closing brace.
fn extract_report(frame: &str) -> &str {
    let idx = frame.find("\"report\":").unwrap_or_else(|| panic!("no report key: {frame}"));
    &frame[idx + "\"report\":".len()..frame.len() - 1]
}

fn parsed(frame: &str) -> JsonValue {
    json::parse(frame).unwrap_or_else(|e| panic!("unparseable frame `{frame}`: {e}"))
}

fn status(frame: &str) -> String {
    parsed(frame).get("status").and_then(JsonValue::as_str).expect("status").to_string()
}

#[test]
fn concurrent_clients_round_trip_golden_frames() {
    let server = serve(cpu_config());
    let addr = server.local_addr();
    let cases: [(String, &str); 2] = [
        (skl_request(), include_str!("golden/skl_triad.json")),
        (rv64_request(), include_str!("golden/rv64_triad.json")),
    ];
    let handles: Vec<_> = cases
        .into_iter()
        .map(|(request, golden)| {
            thread::spawn(move || {
                let mut c = Client::connect(addr);
                for i in 0..3 {
                    let frame = c.round_trip(&request);
                    let v = parsed(&frame);
                    assert_eq!(v.get("status").and_then(JsonValue::as_str), Some("ok"));
                    assert_eq!(v.get("schema_version").and_then(JsonValue::as_u64), Some(5));
                    // The memo works per fingerprint even under
                    // concurrency: each client's repeats hit.
                    let expect_hit = i > 0;
                    assert_eq!(
                        v.get("memo_hit").and_then(JsonValue::as_bool),
                        Some(expect_hit),
                        "request {i}: {frame}"
                    );
                    assert_eq!(extract_report(&frame), golden.trim_end(), "request {i}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    server.shutdown();
    server.join();
}

#[test]
fn memo_hits_are_pinned_in_stats() {
    let server = serve(cpu_config());
    let mut c = Client::connect(server.local_addr());
    let request = skl_request();
    let first = c.round_trip(&request);
    assert!(first.contains("\"memo_hit\":false"), "{first}");
    let second = c.round_trip(&request);
    assert!(second.contains("\"memo_hit\":true"), "{second}");
    assert_eq!(extract_report(&first), extract_report(&second));

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    let field = |k: &str| stats.get(k).and_then(JsonValue::as_u64).expect(k);
    assert_eq!(field("served"), 2);
    assert_eq!(field("analyses"), 1, "second request must not re-analyze");
    assert_eq!(field("memo_hits"), 1);
    assert_eq!(field("memo_misses"), 1);
    assert_eq!(field("errors"), 0);
    assert_eq!(field("overloaded"), 0);
    assert_eq!(field("memo_len"), 1);
    let depths = stats.get("queue_depths").and_then(JsonValue::as_array).expect("queue_depths");
    assert_eq!(depths.len(), 2, "one gauge per shard");
    server.shutdown();
    server.join();
}

#[test]
fn saturated_queue_answers_overloaded_then_recovers() {
    let server = serve(ServeConfig {
        shards: 1,
        queue_depth: 1,
        test_ops: true,
        ..cpu_config()
    });
    let addr = server.local_addr();
    // Occupy the single worker; the sleep job leaves the 1-slot queue
    // buffer free once dequeued.
    let mut blocker = Client::connect(addr);
    blocker.send("{\"op\":\"sleep\",\"ms\":600}");
    thread::sleep(Duration::from_millis(200));
    // Fill the queue slot behind the sleeping job (no reply yet).
    let mut queued = Client::connect(addr);
    queued.send(&skl_request());
    thread::sleep(Duration::from_millis(100));
    // Queue full: the third client gets structured backpressure
    // immediately rather than blocking.
    let mut rejected = Client::connect(addr);
    let frame = rejected.round_trip(&rv64_request());
    let v = parsed(&frame);
    assert_eq!(v.get("status").and_then(JsonValue::as_str), Some("overloaded"), "{frame}");
    assert_eq!(v.get("shard").and_then(JsonValue::as_u64), Some(0));
    assert!(v.get("queue_depth").and_then(JsonValue::as_u64).is_some(), "{frame}");
    // A full 1×1 deployment is at the auto shed threshold, so the
    // rejection frame reports degraded mode.
    assert_eq!(v.get("shedding").and_then(JsonValue::as_bool), Some(true), "{frame}");

    // The queued analyze completes once the worker wakes.
    assert_eq!(status(&queued.recv()), "ok");
    assert_eq!(status(&blocker.recv()), "ok");
    // Same rejected connection, post-saturation: retry succeeds.
    let mut ok = false;
    for _ in 0..50 {
        let frame = rejected.round_trip(&rv64_request());
        if status(&frame) == "ok" {
            ok = true;
            break;
        }
        thread::sleep(Duration::from_millis(100));
    }
    assert!(ok, "retry after saturation never succeeded");
    server.shutdown();
    server.join();
}

#[test]
fn malformed_frames_error_and_the_connection_survives() {
    let server = serve(cpu_config());
    let mut c = Client::connect(server.local_addr());

    let frame = c.round_trip("not json");
    let v = parsed(&frame);
    assert_eq!(v.get("status").and_then(JsonValue::as_str), Some("error"), "{frame}");
    let kind = v.get("error").and_then(|e| e.get("kind")).and_then(JsonValue::as_str);
    assert_eq!(kind, Some("bad_request"), "{frame}");

    // Analysis errors are structured too, with the library error kind.
    let frame = c.round_trip(
        "{\"op\":\"analyze\",\"arch\":\"mips\",\"source\":\".L1:\\nnop\\n\"}",
    );
    let v = parsed(&frame);
    assert_eq!(v.get("status").and_then(JsonValue::as_str), Some("error"), "{frame}");
    let kind = v.get("error").and_then(|e| e.get("kind")).and_then(JsonValue::as_str);
    assert_eq!(kind, Some("unknown_arch"), "{frame}");

    // Same connection, still serving.
    let frame = c.round_trip(&skl_request());
    assert_eq!(status(&frame), "ok");

    // Bad frames are counted as errors but never as served analyses.
    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stats.get("served").and_then(JsonValue::as_u64), Some(2));
    assert_eq!(stats.get("errors").and_then(JsonValue::as_u64), Some(2));
    server.shutdown();
    server.join();
}

#[test]
fn reload_models_rescans_the_models_dir_into_live_shards() {
    // One model imported from the vendored uops.info fixture is present
    // at bind time; a second is dropped into the directory later and
    // must become analyzable after a wire `reload_models` — no restart.
    let dir = std::env::temp_dir().join(format!("osaca-serve-reload-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let xml = include_str!("fixtures/uops_trimmed.xml");
    let clx = osaca::zoo::import_model(xml, "clx").expect("import clx");
    std::fs::write(dir.join("clx.mdb"), &clx.text).unwrap();

    let server = serve(ServeConfig {
        models_dir: Some(dir.display().to_string()),
        ..cpu_config()
    });
    let mut c = Client::connect(server.local_addr());
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let request = |arch: &str| {
        format!(
            "{{\"op\":\"analyze\",\"arch\":\"{arch}\",\"source\":{},\
             \"passes\":[\"throughput\"],\"unroll\":{}}}",
            json_string(w.source),
            w.unroll
        )
    };

    // The bind-time scan registered `clx`; `icl` does not exist yet.
    assert_eq!(status(&c.round_trip(&request("clx"))), "ok");
    let frame = c.round_trip(&request("icl"));
    let v = parsed(&frame);
    assert_eq!(v.get("status").and_then(JsonValue::as_str), Some("error"), "{frame}");
    let kind = v.get("error").and_then(|e| e.get("kind")).and_then(JsonValue::as_str);
    assert_eq!(kind, Some("unknown_arch"), "{frame}");

    // Drop the second model in and reload over the wire.
    let icl = osaca::zoo::import_model(xml, "icl").expect("import icl");
    std::fs::write(dir.join("icl.mdb"), &icl.text).unwrap();
    assert_eq!(status(&c.round_trip("{\"op\":\"reload_models\"}")), "ok");
    assert_eq!(status(&c.round_trip(&request("icl"))), "ok");

    // `stats` counts completed scans: bind-time + the wire reload (the
    // counter is process-global, so other tests may add more).
    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    let reloads = stats.get("model_reloads").and_then(JsonValue::as_u64).expect("model_reloads");
    assert!(reloads >= 2, "expected at least bind + reload scans, got {reloads}");

    server.shutdown();
    server.join();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn wire_shutdown_acknowledges_and_drains() {
    let server = serve(cpu_config());
    let addr = server.local_addr();
    let mut c = Client::connect(addr);
    assert_eq!(status(&c.round_trip(&skl_request())), "ok");
    let bye = c.round_trip("{\"op\":\"shutdown\"}");
    assert_eq!(parsed(&bye).get("status").and_then(JsonValue::as_str), Some("bye"), "{bye}");
    // join() returns only after the accept loop, every connection and
    // every shard worker has wound down.
    server.join();
    // The listener is gone: new connections are refused.
    assert!(TcpStream::connect(addr).is_err(), "listener survived drain");
}
