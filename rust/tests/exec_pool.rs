//! ISSUE-8: semantics of the unified work-stealing executor
//! (`osaca::exec`) and its serve-layer deployment.
//!
//! Pinned here, as the contract every absorbed call site (api pool,
//! serve shards, coordinator solver) relies on:
//!
//! * per-home FIFO: a single worker executes its deque in submission
//!   order, and slot-indexed result assembly is deterministic however
//!   many workers race;
//! * supervision parity: a job panicking on a *stolen* worker is
//!   classified, counted and recovered exactly like one panicking on
//!   its home worker;
//! * backpressure: a blocking submit parks until the home deque has
//!   space, never dropping the job;
//! * drain: `close` + `join` runs every accepted job — zero lost jobs;
//! * shard affinity is a *hint*: a 100% single-arch request stream
//!   still spreads across all serve workers via stealing.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use osaca::api::Backend;
use osaca::exec::{ExecConfig, Executor, Job};
use osaca::report::emit::json_string;
use osaca::serve::json::{self, JsonValue};
use osaca::serve::{ServeConfig, Server};
use osaca::workloads;

fn pool(workers: usize, queue_depth: usize) -> Executor<()> {
    Executor::new(
        ExecConfig {
            workers,
            queue_depth,
            name: "exec-pool-test".to_string(),
            ..Default::default()
        },
        |_worker| (),
    )
}

/// One worker, one home deque: execution order is submission order.
#[test]
fn single_worker_executes_in_submission_order() {
    let exec = pool(1, 64);
    let (tx, rx) = mpsc::channel();
    for i in 0..32usize {
        let tx = tx.clone();
        exec.submit(
            Some(0),
            Job::new(move |_ctx| {
                tx.send(i).unwrap();
            }),
        )
        .unwrap_or_else(|_| panic!("submit {i}"));
    }
    drop(tx);
    let order: Vec<usize> = rx.iter().collect();
    assert_eq!(order, (0..32).collect::<Vec<_>>());
    exec.close();
    exec.join();
}

/// Many workers race over affinity-free submissions, but slot-indexed
/// assembly (the api batch pattern) makes the result deterministic:
/// every slot filled exactly once with its own job's output.
#[test]
fn slot_assembly_is_deterministic_across_workers() {
    let exec = pool(4, 64);
    let (tx, rx) = mpsc::channel::<(usize, usize)>();
    for i in 0..64usize {
        let tx = tx.clone();
        exec.submit(
            None,
            Job::new(move |_ctx| {
                tx.send((i, i * i)).unwrap();
            }),
        )
        .unwrap_or_else(|_| panic!("submit {i}"));
    }
    drop(tx);
    let mut slots = vec![None; 64];
    for (i, v) in rx {
        assert!(slots[i].replace(v).is_none(), "slot {i} answered twice");
    }
    let out: Vec<usize> = slots.into_iter().map(|s| s.expect("slot filled")).collect();
    assert_eq!(out, (0..64).map(|i| i * i).collect::<Vec<_>>());
    exec.close();
    exec.join();
}

/// Run one panicking job and report the category supervision assigned.
fn categorize(exec: &Executor<()>, home: Option<usize>, payload: &'static str) -> String {
    let (tx, rx) = mpsc::channel();
    exec.submit(
        home,
        Job::new(move |_ctx| std::panic::panic_any(payload))
            .on_panic(move |category| tx.send(category.to_string()).unwrap()),
    )
    .unwrap_or_else(|_| panic!("submit panic job"));
    rx.recv_timeout(Duration::from_secs(10)).expect("on_panic ran")
}

/// Supervision parity, home vs stolen: block worker 0 so a job homed
/// to it is provably stolen by worker 1, and check the stolen panic is
/// categorized and counted exactly like a home-worker panic.
#[test]
fn stolen_panic_classifies_like_home_panic() {
    let exec = pool(2, 64);

    // Occupy worker 0 until released, so anything homed to it backs up.
    let (started_tx, started_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    exec.submit(
        Some(0),
        Job::new(move |_ctx| {
            started_tx.send(()).unwrap();
            let _ = release_rx.recv_timeout(Duration::from_secs(10));
        }),
    )
    .unwrap_or_else(|_| panic!("submit blocker"));
    started_rx.recv_timeout(Duration::from_secs(10)).expect("blocker started");

    // Homed to the busy worker: only a steal can run it.
    let stolen = categorize(&exec, Some(0), "chaos: injected worker panic");
    assert_eq!(stolen, "injected_chaos_panic");
    assert!(exec.stats().steals.load(Ordering::Relaxed) >= 1, "panic job was not stolen");

    release_tx.send(()).unwrap();

    // Same payload classes on an idle pool (home execution path).
    assert_eq!(categorize(&exec, Some(0), "chaos: injected worker panic"), stolen);
    assert_eq!(categorize(&exec, Some(1), "test-op: injected worker panic"), "injected_test_panic");

    // Every panic rebuilt a context, wherever the job actually ran.
    assert_eq!(exec.stats().panics.load(Ordering::Relaxed), 3);
    assert_eq!(exec.stats().worker_restarts.load(Ordering::Relaxed), 3);
    exec.close();
    exec.join();

    // The panicked jobs were consumed (blocker + 3 panics).
    let executed: u64 =
        exec.worker_stats().iter().map(|w| w.executed.load(Ordering::Relaxed)).sum();
    assert_eq!(executed, 4);
}

/// A blocking submit to a full home deque parks until a slot frees,
/// then the job runs — backpressure never drops work.
#[test]
fn blocking_submit_waits_for_space() {
    let exec = Arc::new(pool(1, 1));

    let (started_tx, started_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    exec.submit(
        Some(0),
        Job::new(move |_ctx| {
            started_tx.send(()).unwrap();
            let _ = release_rx.recv_timeout(Duration::from_secs(10));
        }),
    )
    .unwrap_or_else(|_| panic!("submit blocker"));
    started_rx.recv_timeout(Duration::from_secs(10)).expect("blocker started");
    // Fill the single deque slot behind the in-flight blocker.
    let (tx, rx) = mpsc::channel();
    let tx2 = tx.clone();
    exec.submit(Some(0), Job::new(move |_ctx| tx2.send(1).unwrap()))
        .unwrap_or_else(|_| panic!("fill deque"));

    let exec2 = exec.clone();
    let submitter = thread::spawn(move || {
        exec2
            .submit(Some(0), Job::new(move |_ctx| tx.send(2).unwrap()))
            .unwrap_or_else(|_| panic!("blocked submit"));
    });
    // The deque is full: the submit must still be parked.
    thread::sleep(Duration::from_millis(100));
    assert!(!submitter.is_finished(), "submit returned while the deque was full");

    release_tx.send(()).unwrap();
    submitter.join().expect("submitter thread");
    assert_eq!(rx.recv_timeout(Duration::from_secs(10)), Ok(1));
    assert_eq!(rx.recv_timeout(Duration::from_secs(10)), Ok(2));
    exec.close();
    exec.join();
}

/// `close` + `join` drains every accepted job across all workers: the
/// executed sum equals the accepted count and nothing stays queued.
#[test]
fn drain_runs_every_accepted_job() {
    let exec = pool(4, 64);
    let ran = Arc::new(AtomicUsize::new(0));
    let mut accepted = 0usize;
    for i in 0..200usize {
        let ran = ran.clone();
        let job = Job::new(move |_ctx| {
            ran.fetch_add(1, Ordering::Relaxed);
        });
        // Spread over homes and the injector like real call sites do.
        let home = if i % 3 == 0 { None } else { Some(i % 4) };
        if exec.submit(home, job).is_ok() {
            accepted += 1;
        }
    }
    assert_eq!(accepted, 200);
    exec.close();
    exec.join();
    assert_eq!(ran.load(Ordering::Relaxed), 200, "jobs lost across drain");
    let executed: u64 =
        exec.worker_stats().iter().map(|w| w.executed.load(Ordering::Relaxed)).sum();
    assert_eq!(executed, 200);
    assert_eq!(exec.stats().queued.load(Ordering::Relaxed), 0);
    assert_eq!(exec.stats().in_flight.load(Ordering::Relaxed), 0);
}

/// A line-oriented test client over one persistent connection.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { stream, reader }
    }

    fn send(&mut self, frame: &str) {
        self.stream.write_all(frame.as_bytes()).expect("send frame");
        self.stream.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read frame");
        assert!(n > 0, "server closed the connection unexpectedly");
        line.trim_end().to_string()
    }
}

fn status(frame: &str) -> String {
    json::parse(frame)
        .unwrap_or_else(|e| panic!("unparseable frame `{frame}`: {e}"))
        .get("status")
        .and_then(JsonValue::as_str)
        .expect("status")
        .to_string()
}

/// Shard affinity is a hint, not a partition: a request stream that is
/// 100% one architecture homes every job to one shard, yet the idle
/// worker steals from the hot deque and both workers end up executing
/// analyses. Simulation requests keep each job on a worker for
/// milliseconds, so the backlog provably outlives the steal scan.
#[test]
fn hot_arch_stream_is_stolen_by_idle_workers() {
    let server = Server::bind(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        backend: Backend::Cpu,
        shards: 2,
        memo_cap: 0, // every request is a genuine analysis
        ..Default::default()
    })
    .expect("bind server");
    let addr = server.local_addr();

    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let n = 12;
    // One outstanding request per connection (the conn thread waits for
    // its reply), so concurrency comes from many clients.
    let mut clients: Vec<Client> = (0..n).map(|_| Client::connect(addr)).collect();
    for (i, c) in clients.iter_mut().enumerate() {
        // Unique names keep every request distinct even with a memo.
        c.send(&format!(
            "{{\"op\":\"analyze\",\"name\":\"hot-{i}\",\"arch\":\"skl\",\"source\":{},\
             \"passes\":[\"simulate\"],\"unroll\":{},\"format\":\"json\"}}",
            json_string(w.source),
            w.unroll
        ));
    }
    for c in clients.iter_mut() {
        let frame = c.recv();
        assert_eq!(status(&frame), "ok", "{frame}");
    }

    assert!(
        server.exec_stats().steals.load(Ordering::Relaxed) > 0,
        "idle worker never stole from the hot shard"
    );
    let per_worker: Vec<u64> =
        server.worker_stats().iter().map(|w| w.executed.load(Ordering::Relaxed)).collect();
    assert_eq!(per_worker.len(), 2);
    assert_eq!(per_worker.iter().sum::<u64>(), n as u64);
    assert!(
        per_worker.iter().all(|&e| e > 0),
        "all workers must participate in a single-arch stream: {per_worker:?}"
    );
    server.shutdown();
    server.join();
}
