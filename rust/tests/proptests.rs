//! Property tests on coordinator/analyzer/simulator invariants, using
//! the in-repo seeded generator (proptest is not vendored offline).

use osaca::analyzer::analyze;
use osaca::asm::extract_kernel;
use osaca::mdb::{skylake, zen, MachineModel};
use osaca::proplite::{for_cases, Rng};
use osaca::runtime::{solve_cpu, EncodedKernel, MAX_PORTS, MAX_UOPS};
use osaca::sim::{simulate, SimConfig};

/// Generate a random—but valid—loop kernel from the forms both DBs know.
fn random_kernel(rng: &mut Rng) -> String {
    const POOL: &[&str] = &[
        "vaddpd %xmm{a}, %xmm{b}, %xmm{c}",
        "vmulpd %xmm{a}, %xmm{b}, %xmm{c}",
        "vfmadd132pd %xmm{a}, %xmm{b}, %xmm{c}",
        "vaddsd %xmm{a}, %xmm{b}, %xmm{c}",
        "vmovaps (%r8,%rax), %xmm{c}",
        "vmovaps %xmm{a}, (%r9,%rax)",
        "vpaddd %xmm{a}, %xmm{b}, %xmm{c}",
        "vdivsd %xmm{a}, %xmm{b}, %xmm{c}",
        "addl $1, %esi",
        "vxorpd %xmm{z}, %xmm{z}, %xmm{z}",
    ];
    let n = rng.range(1, 12);
    let mut body = String::new();
    for _ in 0..n {
        let t = *rng.pick(POOL);
        let line = t
            .replace("{a}", &format!("{}", rng.range(0, 15)))
            .replace("{b}", &format!("{}", rng.range(0, 15)))
            .replace("{c}", &format!("{}", rng.range(0, 15)))
            .replace("{z}", &format!("{}", rng.range(0, 15)));
        body.push_str(&line);
        body.push('\n');
    }
    format!(".L0:\n{body}addq $16, %rax\ncmpq %rdx, %rax\njne .L0\n")
}

fn machines() -> [MachineModel; 2] {
    [skylake(), zen()]
}

#[test]
fn prop_analysis_total_is_max_of_ports() {
    for_cases(40, |rng| {
        let src = random_kernel(rng);
        for m in machines() {
            let k = extract_kernel("p", &src).unwrap();
            let a = analyze(&k, &m).unwrap();
            let max = a.totals.iter().cloned().fold(0.0f32, f32::max);
            assert!((a.cy_per_asm_iter - max).abs() < 1e-5);
            assert!(a.totals.iter().all(|&t| t >= 0.0));
            // Totals equal the per-line sums.
            for p in 0..m.n_ports() {
                let s: f32 = a.lines.iter().map(|l| l.occupancy[p]).sum();
                assert!((s - a.totals[p]).abs() < 1e-4);
            }
        }
    });
}

#[test]
fn prop_simulation_never_beats_port_bound() {
    // The simulator (imperfect scheduling, finite resources) can never
    // be faster than the analyzer's idealized throughput bound... except
    // where the hardware knows shortcuts the model does not (zero
    // idioms, fused compares) — so compare against the shortcut-aware
    // encoding instead (the baseline's uniform number).
    for_cases(25, |rng| {
        let src = random_kernel(rng);
        for m in machines() {
            let k = extract_kernel("p", &src).unwrap();
            let cpu = osaca::baseline::predict_cpu(&k, &m).unwrap();
            let meas = simulate(&k, &m, SimConfig { iterations: 200, warmup: 60 }).unwrap();
            // Hidden loads (Zen) make the analyzer slightly optimistic;
            // allow a small epsilon.
            assert!(
                meas.cycles_per_iteration >= cpu.cy_per_asm_iter as f64 * 0.92 - 0.1,
                "{}: measured {} < balanced bound {}\n{src}",
                m.name,
                meas.cycles_per_iteration,
                cpu.cy_per_asm_iter
            );
        }
    });
}

#[test]
fn prop_solver_mass_conservation_and_order() {
    for_cases(60, |rng| {
        let mut enc = EncodedKernel::empty();
        let rows = rng.range(1, MAX_UOPS.min(24));
        let mut total = 0f32;
        for r in 0..rows {
            let nports = rng.range(1, 4);
            let mut ports = Vec::new();
            for _ in 0..nports {
                ports.push(rng.range(0, MAX_PORTS - 1));
            }
            ports.dedup();
            let cost = rng.f32() * 4.0;
            enc.push_uop(r, &ports, cost).unwrap();
            total += cost;
        }
        let out = &solve_cpu(&[enc], 32)[0];
        let su: f32 = out.press_uniform.iter().sum();
        let sb: f32 = out.press_balanced.iter().sum();
        assert!((su - total).abs() < 1e-3, "{su} vs {total}");
        assert!((sb - total).abs() < 1e-2, "{sb} vs {total}");
        // Balancing can only help the bottleneck.
        assert!(out.tp_balanced <= out.tp_uniform + 1e-3);
        // Lower bound sanity channel.
        assert!(out.crit_lower <= out.tp_balanced + 1e-3);
    });
}

#[test]
fn prop_mdb_roundtrip_arbitrary_subsets() {
    for_cases(20, |rng| {
        for mut m in machines() {
            // Drop a random subset of entries, serialize, reparse.
            let forms: Vec<_> = m.entries.keys().cloned().collect();
            for f in forms {
                if rng.chance(0.5) {
                    m.entries.remove(&f);
                }
            }
            let text = m.serialize();
            let m2 = MachineModel::parse(&text).unwrap();
            assert_eq!(m.entries.len(), m2.entries.len());
            for (f, e) in &m.entries {
                assert_eq!(e.uops, m2.entries[f].uops, "{f}");
            }
        }
    });
}

#[test]
fn prop_simulator_monotone_in_kernel_growth() {
    // Appending an instruction that writes NO register (a pure store to
    // a fresh stream) never makes the loop faster. (Inserting a
    // register-writing op CAN legitimately speed the loop up by
    // breaking a loop-carried chain — that is not a bug.)
    for_cases(15, |rng| {
        let base = random_kernel(rng);
        let k1 = extract_kernel("p", &base).unwrap();
        let grown = base.replace(
            "addq $16, %rax",
            "vmovaps %xmm0, (%r10,%rax)\naddq $16, %rax",
        );
        let k2 = extract_kernel("p", &grown).unwrap();
        let m = skylake();
        let cfg = SimConfig { iterations: 150, warmup: 50 };
        let a = simulate(&k1, &m, cfg).unwrap().cycles_per_iteration;
        let b = simulate(&k2, &m, cfg).unwrap().cycles_per_iteration;
        assert!(b + 1e-6 >= a * 0.98, "{a} -> {b}\n{base}");
    });
}
