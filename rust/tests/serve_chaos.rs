//! ISSUE-7: fault tolerance in the serving layer, driven over a real
//! TCP socket by the seeded fault injector.
//!
//! Every injected fault class is pinned three ways: the structured
//! wire frame a client observes, the `stats` counter that records it,
//! and proof that the server is still serving afterwards (a recovery
//! request on the same socket must succeed).
//!
//! * A worker panic — via the `panic` test op and via seeded chaos —
//!   answers a redacted `internal_error` frame, the engine is rebuilt,
//!   and the same connection's next request succeeds.
//! * A chaos-delayed reply times out the waiting connection; the late
//!   reply is dropped (never leaks into the retry) but its analysis
//!   still lands in the memo.
//! * A chaos queue stall deterministically blows the deadline of the
//!   request queued behind it (`deadline_exceeded`).
//! * The per-connection token bucket and in-flight cap answer
//!   `rate_limited` frames whose `retry_after_ms` hint works.
//! * Oversized and torn frames never kill the connection.
//! * The byte-bounded memo evicts in LRU order under budget pressure.
//! * A saturated server sheds fresh misses but still answers memo hits
//!   and `stats` — the degradation ladder never trades introspection.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::thread;
use std::time::{Duration, Instant};

use osaca::api::Backend;
use osaca::report::emit::json_string;
use osaca::serve::faults::{Fault, FaultPlan};
use osaca::serve::json::{self, JsonValue};
use osaca::serve::{ServeConfig, Server};
use osaca::workloads;

/// A line-oriented test client over one persistent connection.
struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { stream, reader }
    }

    fn send(&mut self, frame: &str) {
        self.stream.write_all(frame.as_bytes()).expect("send frame");
        self.stream.write_all(b"\n").expect("send newline");
    }

    /// Raw bytes, no terminator — for torn/noisy wire tests.
    fn send_raw(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).expect("send raw");
        self.stream.flush().expect("flush");
    }

    fn recv(&mut self) -> String {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("read frame");
        assert!(n > 0, "server closed the connection unexpectedly");
        line.trim_end().to_string()
    }

    fn round_trip(&mut self, frame: &str) -> String {
        self.send(frame);
        self.recv()
    }
}

fn serve(cfg: ServeConfig) -> Server {
    Server::bind(cfg).expect("bind server")
}

fn cpu_config() -> ServeConfig {
    ServeConfig { addr: "127.0.0.1:0".to_string(), backend: Backend::Cpu, ..Default::default() }
}

fn skl_request() -> String {
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    format!(
        "{{\"op\":\"analyze\",\"name\":\"{}\",\"arch\":\"skl\",\"source\":{},\
         \"passes\":[\"throughput\"],\"unroll\":{},\"format\":\"json\"}}",
        w.name(),
        json_string(w.source),
        w.unroll
    )
}

fn skl_request_with_deadline(deadline_ms: u64) -> String {
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    format!(
        "{{\"op\":\"analyze\",\"name\":\"{}\",\"arch\":\"skl\",\"source\":{},\
         \"passes\":[\"throughput\"],\"unroll\":{},\"format\":\"json\",\
         \"deadline_ms\":{}}}",
        w.name(),
        json_string(w.source),
        w.unroll,
        deadline_ms
    )
}

fn rv64_request() -> String {
    let w = workloads::find("triad", "rv64", "-O2").unwrap();
    format!(
        "{{\"op\":\"analyze\",\"name\":\"{}\",\"arch\":\"rv64\",\"source\":{},\
         \"passes\":[\"throughput\",\"critpath\"],\"frontend_bound\":true,\
         \"unroll\":{},\"format\":\"json\"}}",
        w.name(),
        json_string(w.source),
        w.unroll
    )
}

fn parsed(frame: &str) -> JsonValue {
    json::parse(frame).unwrap_or_else(|e| panic!("unparseable frame `{frame}`: {e}"))
}

fn status(frame: &str) -> String {
    parsed(frame).get("status").and_then(JsonValue::as_str).expect("status").to_string()
}

fn error_kind(frame: &str) -> String {
    parsed(frame)
        .get("error")
        .and_then(|e| e.get("kind"))
        .and_then(JsonValue::as_str)
        .unwrap_or_else(|| panic!("no error kind: {frame}"))
        .to_string()
}

fn error_message(frame: &str) -> String {
    parsed(frame)
        .get("error")
        .and_then(|e| e.get("message"))
        .and_then(JsonValue::as_str)
        .unwrap_or_else(|| panic!("no error message: {frame}"))
        .to_string()
}

fn stat(stats: &JsonValue, key: &str) -> u64 {
    stats.get(key).and_then(JsonValue::as_u64).unwrap_or_else(|| panic!("missing stat {key}"))
}

/// Smallest seed satisfying a schedule predicate — tests pin fault
/// sequences without hardcoding magic numbers next to the hash.
fn seed_where(pred: impl Fn(u64) -> bool) -> u64 {
    (0u64..1_000_000).find(|&s| pred(s)).expect("no seed in 1e6 satisfies the schedule predicate")
}

/// The `panic` test op: the worker dies mid-request, the client gets a
/// redacted `internal_error` frame, the worker restarts with a fresh
/// engine, and the same connection keeps being served (the memo
/// survives the restart — it lives outside the worker).
#[test]
fn worker_panic_answers_redacted_error_and_recovers() {
    let server = serve(ServeConfig { shards: 1, test_ops: true, ..cpu_config() });
    let mut c = Client::connect(server.local_addr());

    assert_eq!(status(&c.round_trip(&skl_request())), "ok");
    let frame = c.round_trip("{\"op\":\"panic\"}");
    assert_eq!(status(&frame), "error", "{frame}");
    assert_eq!(error_kind(&frame), "internal_error", "{frame}");
    // The panic payload is redacted to a category — payload text is
    // not a wire surface.
    assert_eq!(error_message(&frame), "injected_test_panic", "{frame}");

    // Same connection, same shard: still serving, memo intact.
    let after = c.round_trip(&skl_request());
    assert_eq!(status(&after), "ok", "{after}");
    assert!(after.contains("\"memo_hit\":true"), "memo must survive the restart: {after}");

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "panics"), 1);
    assert_eq!(stat(&stats, "worker_restarts"), 1);
    assert_eq!(stat(&stats, "errors"), 1);
    assert_eq!(stat(&stats, "analyses"), 1);
    assert_eq!(stat(&stats, "memo_hits"), 1);
    assert_eq!(stat(&stats, "served"), 2, "the panic op is not a served analysis");
    server.shutdown();
    server.join();
}

/// Seeded chaos: a seed chosen so dispatch 0 panics produces the same
/// redacted frame, and the connection recovers within a few retries
/// (clean dispatches dominate the schedule by construction).
#[test]
fn chaos_panic_is_deterministic_and_recoverable() {
    let seed = FaultPlan::find_seed(|f| f == Some(Fault::Panic));
    let server = serve(ServeConfig { shards: 1, chaos_seed: Some(seed), ..cpu_config() });
    let mut c = Client::connect(server.local_addr());

    let frame = c.round_trip(&skl_request());
    assert_eq!(status(&frame), "error", "{frame}");
    assert_eq!(error_kind(&frame), "internal_error", "{frame}");
    assert_eq!(error_message(&frame), "injected_chaos_panic", "{frame}");

    let mut recovered = false;
    for _ in 0..20 {
        if status(&c.round_trip(&skl_request())) == "ok" {
            recovered = true;
            break;
        }
    }
    assert!(recovered, "server never recovered from seeded chaos panics");

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert!(stat(&stats, "panics") >= 1);
    assert_eq!(stat(&stats, "worker_restarts"), stat(&stats, "panics"));
    server.shutdown();
    server.join();
}

/// A chaos-delayed reply exceeds the reply timeout: the connection
/// gets `solver_timeout`, the late reply is dropped harmlessly, and —
/// because the analysis itself completed before the delay — the retry
/// is answered from the memo. Pins that stale replies cannot leak into
/// later requests.
#[test]
fn chaos_delayed_reply_times_out_without_leaking() {
    let seed = seed_where(|s| {
        matches!(FaultPlan::fault_for(s, 0), Some(Fault::DelayReply { ms }) if ms >= 78)
            && FaultPlan::fault_for(s, 1).is_none()
    });
    let server = serve(ServeConfig {
        shards: 1,
        chaos_seed: Some(seed),
        reply_timeout: Duration::from_millis(70),
        ..cpu_config()
    });
    let mut c = Client::connect(server.local_addr());

    // Delay ≥ 78ms > 70ms timeout, unconditionally: the first analyze
    // times out no matter how fast the analysis runs.
    let frame = c.round_trip(&skl_request());
    assert_eq!(status(&frame), "error", "{frame}");
    assert_eq!(error_kind(&frame), "solver_timeout", "{frame}");

    // Let the worker finish the delayed send (into a dropped channel).
    thread::sleep(Duration::from_millis(600));
    let retry = c.round_trip(&skl_request());
    assert_eq!(status(&retry), "ok", "{retry}");
    assert!(retry.contains("\"memo_hit\":true"), "timed-out work must still memoize: {retry}");

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "errors"), 1);
    assert_eq!(stat(&stats, "memo_hits"), 1);
    assert_eq!(stat(&stats, "analyses"), 1);
    assert_eq!(stat(&stats, "panics"), 0);
    assert_eq!(stat(&stats, "served"), 2);
    server.shutdown();
    server.join();
}

/// A chaos queue stall holds the worker ≥ 100ms, so a request queued
/// behind it with a 30ms deadline is provably expired at dispatch and
/// answered `deadline_exceeded` instead of being analyzed late.
#[test]
fn chaos_queue_stall_expires_queued_deadlines() {
    let seed = seed_where(|s| {
        matches!(FaultPlan::fault_for(s, 0), Some(Fault::StallQueue { ms }) if ms >= 100)
    });
    let server = serve(ServeConfig { shards: 1, chaos_seed: Some(seed), ..cpu_config() });
    let addr = server.local_addr();

    let started = Instant::now();
    let mut stalled = Client::connect(addr);
    stalled.send(&skl_request());
    // Long enough that the second submission provably queues behind
    // the first; its deadline (50+30=80ms) still expires inside the
    // ≥100ms stall.
    thread::sleep(Duration::from_millis(50));
    let mut expired = Client::connect(addr);
    expired.send(&skl_request_with_deadline(30));

    // The stalled request completes (stall delays, never fails)...
    let first = stalled.recv();
    assert_eq!(status(&first), "ok", "{first}");
    assert!(started.elapsed() >= Duration::from_millis(100), "stall was not injected");
    // ...and the one queued behind it has blown its deadline.
    let second = expired.recv();
    assert_eq!(status(&second), "error", "{second}");
    assert_eq!(error_kind(&second), "deadline_exceeded", "{second}");

    let mut c = Client::connect(addr);
    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "deadline_expired"), 1);
    assert_eq!(stat(&stats, "errors"), 1);
    assert_eq!(stat(&stats, "analyses"), 1, "an expired request must never be analyzed");
    assert_eq!(stat(&stats, "served"), 2);
    server.shutdown();
    server.join();
}

/// The per-connection token bucket: burst admits back-to-back
/// requests, the next is `rate_limited` with a usable retry hint, and
/// other connections are unaffected.
#[test]
fn token_bucket_limits_then_refills() {
    let server = serve(ServeConfig { max_rps: 1.0, burst: 2, ..cpu_config() });
    let addr = server.local_addr();
    let mut c = Client::connect(addr);

    assert_eq!(status(&c.round_trip(&skl_request())), "ok");
    assert_eq!(status(&c.round_trip(&skl_request())), "ok");
    let frame = c.round_trip(&skl_request());
    let v = parsed(&frame);
    assert_eq!(v.get("status").and_then(JsonValue::as_str), Some("rate_limited"), "{frame}");
    assert_eq!(v.get("reason").and_then(JsonValue::as_str), Some("rps"), "{frame}");
    let retry_ms = v.get("retry_after_ms").and_then(JsonValue::as_u64).expect("retry_after_ms");
    assert!((1..=1000).contains(&retry_ms), "retry_after_ms out of range: {frame}");

    // The limit is per connection: a second client is admitted now.
    let mut other = Client::connect(addr);
    assert_eq!(status(&other.round_trip(&skl_request())), "ok");

    // Honoring the hint (plus slack) gets the first client served.
    thread::sleep(Duration::from_millis(retry_ms + 100));
    assert_eq!(status(&c.round_trip(&skl_request())), "ok");

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "rate_limited"), 1);
    assert_eq!(stat(&stats, "served"), 5);
    server.shutdown();
    server.join();
}

/// The per-connection in-flight cap: while one analyze is still queued
/// (its reply timed out but the job is alive), the same connection's
/// next analyze is refused with `reason:"inflight"`.
#[test]
fn inflight_cap_rejects_while_a_request_is_outstanding() {
    let server = serve(ServeConfig {
        shards: 1,
        test_ops: true,
        max_inflight: 1,
        reply_timeout: Duration::from_millis(100),
        ..cpu_config()
    });
    let addr = server.local_addr();
    let mut blocker = Client::connect(addr);
    blocker.send("{\"op\":\"sleep\",\"ms\":600}");
    thread::sleep(Duration::from_millis(100));

    let mut c = Client::connect(addr);
    // Queued behind the sleeper, the reply times out — but the job is
    // still in flight on this connection's gauge.
    let first = c.round_trip(&skl_request());
    assert_eq!(error_kind(&first), "solver_timeout", "{first}");
    let second = c.round_trip(&skl_request());
    let v = parsed(&second);
    assert_eq!(v.get("status").and_then(JsonValue::as_str), Some("rate_limited"), "{second}");
    assert_eq!(v.get("reason").and_then(JsonValue::as_str), Some("inflight"), "{second}");
    assert_eq!(v.get("retry_after_ms").and_then(JsonValue::as_u64), Some(50), "{second}");

    // Once the sleeper and the queued analyze finish, the gauge drops
    // and the connection is served again (from the memo: the
    // timed-out analyze still completed).
    thread::sleep(Duration::from_millis(900));
    let third = c.round_trip(&skl_request());
    assert_eq!(status(&third), "ok", "{third}");
    assert!(third.contains("\"memo_hit\":true"), "{third}");

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "rate_limited"), 1);
    assert_eq!(stat(&stats, "errors"), 1);
    assert_eq!(stat(&stats, "analyses"), 1);
    server.shutdown();
    server.join();
}

/// Frames over the configured bound answer `frame_too_large` and are
/// skipped with bounded memory; the connection keeps serving.
#[test]
fn oversized_frame_is_rejected_and_skipped() {
    let server = serve(ServeConfig { max_frame_bytes: 4096, ..cpu_config() });
    let mut c = Client::connect(server.local_addr());

    let frame = c.round_trip(&"x".repeat(10_000));
    assert_eq!(status(&frame), "error", "{frame}");
    assert_eq!(error_kind(&frame), "frame_too_large", "{frame}");
    assert!(error_message(&frame).contains("4096"), "{frame}");

    // The oversized line was discarded, not buffered: the next frame
    // on the same connection parses and serves normally.
    assert_eq!(status(&c.round_trip(&skl_request())), "ok");

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "oversized_frames"), 1);
    assert_eq!(stat(&stats, "errors"), 1);
    assert_eq!(stat(&stats, "served"), 1);
    server.shutdown();
    server.join();
}

/// Torn writes, blank lines and `\r\n` terminators reassemble into
/// clean frames — wire noise is invisible to the request layer.
#[test]
fn torn_and_noisy_frames_reassemble() {
    let server = serve(cpu_config());
    let mut c = Client::connect(server.local_addr());

    // Blank CRLF line, then a request torn into three writes.
    c.send_raw(b"\r\n");
    let request = skl_request();
    let bytes = request.as_bytes();
    let (a, rest) = bytes.split_at(bytes.len() / 3);
    let (b, tail) = rest.split_at(rest.len() / 2);
    for chunk in [a, b] {
        c.send_raw(chunk);
        thread::sleep(Duration::from_millis(40));
    }
    c.send_raw(tail);
    c.send_raw(b"\r\n");
    let first = c.recv();
    assert_eq!(status(&first), "ok", "{first}");

    // Empty lines between frames are skipped, not answered.
    c.send_raw(b"\n\n");
    let second = c.round_trip(&request);
    assert_eq!(status(&second), "ok", "{second}");
    assert!(second.contains("\"memo_hit\":true"), "{second}");

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "served"), 2);
    assert_eq!(stat(&stats, "errors"), 0);
    server.shutdown();
    server.join();
}

/// The memo byte budget: set just below the sum of the two golden
/// reports, so the second insert must evict the first (LRU), a re-hit
/// keeps the survivor, and re-inserting the evicted one swaps them
/// back. `memo_bytes` tracks the resident rendered-report bytes.
#[test]
fn memo_byte_budget_evicts_in_lru_order() {
    let skl_len = include_str!("golden/skl_triad.json").trim_end().len();
    let rv64_len = include_str!("golden/rv64_triad.json").trim_end().len();
    let server = serve(ServeConfig {
        shards: 1,
        memo_cap: 8,
        memo_max_bytes: skl_len + rv64_len - 1,
        ..cpu_config()
    });
    let mut c = Client::connect(server.local_addr());

    assert!(c.round_trip(&skl_request()).contains("\"memo_hit\":false"));
    // Inserting rv64 overflows the budget and evicts skl (the LRU).
    assert!(c.round_trip(&rv64_request()).contains("\"memo_hit\":false"));
    assert!(c.round_trip(&rv64_request()).contains("\"memo_hit\":true"));
    // skl was evicted: a miss, whose insert now evicts rv64.
    assert!(c.round_trip(&skl_request()).contains("\"memo_hit\":false"));

    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "memo_len"), 1);
    assert_eq!(stat(&stats, "memo_bytes"), skl_len as u64);
    assert_eq!(stat(&stats, "memo_hits"), 1);
    assert_eq!(stat(&stats, "memo_misses"), 3);
    assert_eq!(stat(&stats, "analyses"), 3);
    server.shutdown();
    server.join();
}

/// The degradation ladder under saturation: a full 1×1 deployment
/// sheds fresh analyze misses (`overloaded` + `shedding:true`) while
/// memo hits — whose queue is provably full — and `stats` still
/// answer. After the load drains, shedding exits via hysteresis and
/// the shed request succeeds on retry.
#[test]
fn load_shed_still_answers_memo_hits_and_stats() {
    let server = serve(ServeConfig {
        shards: 1,
        queue_depth: 1,
        test_ops: true,
        ..cpu_config()
    });
    let addr = server.local_addr();
    let mut c = Client::connect(addr);
    // Warm the memo before saturating.
    assert_eq!(status(&c.round_trip(&skl_request())), "ok");

    // Saturate: one job in flight + one queued = the full gauge.
    let mut blocker = Client::connect(addr);
    blocker.send("{\"op\":\"sleep\",\"ms\":800}");
    thread::sleep(Duration::from_millis(150));
    let mut queued = Client::connect(addr);
    queued.send("{\"op\":\"sleep\",\"ms\":10}");
    thread::sleep(Duration::from_millis(50));

    // A memo hit is served without a queue slot (there is none free).
    let hit = c.round_trip(&skl_request());
    assert_eq!(status(&hit), "ok", "{hit}");
    assert!(hit.contains("\"memo_hit\":true"), "{hit}");
    // A fresh miss is shed with the degraded-mode marker.
    let shed = c.round_trip(&rv64_request());
    let v = parsed(&shed);
    assert_eq!(v.get("status").and_then(JsonValue::as_str), Some("overloaded"), "{shed}");
    assert_eq!(v.get("shedding").and_then(JsonValue::as_bool), Some(true), "{shed}");
    // Introspection survives saturation.
    let stats = parsed(&c.round_trip("{\"op\":\"stats\"}"));
    assert_eq!(stat(&stats, "shed"), 1);
    assert_eq!(stat(&stats, "memo_hits"), 1);
    assert_eq!(stats.get("shedding").and_then(JsonValue::as_bool), Some(true));

    // Drain, then the shed request succeeds on retry.
    assert_eq!(status(&blocker.recv()), "ok");
    assert_eq!(status(&queued.recv()), "ok");
    let mut ok = false;
    for _ in 0..50 {
        if status(&c.round_trip(&rv64_request())) == "ok" {
            ok = true;
            break;
        }
        thread::sleep(Duration::from_millis(100));
    }
    assert!(ok, "retry after shed never succeeded");
    server.shutdown();
    server.join();
}
