//! Integration: the analyzer reproduces the paper's per-line occupancy
//! tables (II, IV, VI, VII) cell-for-cell and the Table I predictions.

use osaca::analyzer::analyze;
use osaca::coordinator::Coordinator;
use osaca::mdb::{skylake, zen};
use osaca::report::experiments::table1;
use osaca::workloads;

fn cell(v: f32, want: f32) -> bool {
    (v - want).abs() < 0.011
}

/// Paper Table II — triad -O3 for Skylake: full footer.
#[test]
fn table2_footer() {
    let m = skylake();
    let a = analyze(&workloads::find("triad", "skl", "-O3").unwrap().kernel(), &m).unwrap();
    let want: &[(&str, f32)] = &[
        ("P0", 1.25),
        ("P1", 1.25),
        ("P2", 2.0),
        ("P3", 2.0),
        ("P4", 1.0),
        ("P5", 0.75),
        ("P6", 0.75),
        ("P7", 0.0),
        ("0DV", 0.0),
    ];
    for (p, v) in want {
        let i = m.port_index(p).unwrap();
        assert!(cell(a.totals[i], *v), "{p}: {} want {v}", a.totals[i]);
    }
    assert!(cell(a.cy_per_asm_iter, 2.0));
}

/// Paper Table IV — triad -O3 for Zen: footer + hidden load.
#[test]
fn table4_footer_and_hidden_load() {
    let m = zen();
    let a = analyze(&workloads::find("triad", "zen", "-O3").unwrap().kernel(), &m).unwrap();
    let want: &[(&str, f32)] = &[
        ("FP0", 1.25),
        ("FP1", 1.25),
        ("FP2", 0.75),
        ("FP3", 0.75),
        ("ALU0", 0.75),
        ("ALU1", 0.75),
        ("ALU2", 0.75),
        ("ALU3", 0.75),
        ("AGU0", 2.0),
        ("AGU1", 2.0),
        ("DV", 0.0),
    ];
    for (p, v) in want {
        let i = m.port_index(p).unwrap();
        assert!(cell(a.totals[i], *v), "{p}: {} want {v}", a.totals[i]);
    }
    // Row 1's load µ-op is parenthesized (hidden behind the store).
    let agu0 = m.port_index("AGU0").unwrap();
    assert!(cell(a.lines[0].hidden[agu0], 0.5));
    assert!(cell(a.lines[0].occupancy[agu0], 0.0));
}

/// Paper Table VI — π -O3 for Skylake: footer incl. 0DV = 16.
#[test]
fn table6_footer() {
    let m = skylake();
    let a = analyze(&workloads::find("pi", "skl", "-O3").unwrap().kernel(), &m).unwrap();
    let want: &[(&str, f32)] = &[
        ("P0", 8.83),
        ("0DV", 16.0),
        ("P1", 4.83),
        ("P2", 0.0),
        ("P3", 0.0),
        ("P4", 0.0),
        ("P5", 3.83),
        ("P6", 0.5),
        ("P7", 0.0),
    ];
    for (p, v) in want {
        let i = m.port_index(p).unwrap();
        assert!(cell(a.totals[i], *v), "{p}: {} want {v}", a.totals[i]);
    }
    assert!(cell(a.cy_per_asm_iter, 16.0));
    assert!(cell(a.cy_per_source_it(8), 2.0));
    // Divider rows: vdivpd = 1.00 on P0 + 8.00 on 0DV.
    let dv = m.port_index("0DV").unwrap();
    let p0 = m.port_index("P0").unwrap();
    let div_lines: Vec<_> =
        a.lines.iter().filter(|l| l.text.starts_with("vdivpd")).collect();
    assert_eq!(div_lines.len(), 2);
    for l in div_lines {
        assert!(cell(l.occupancy[dv], 8.0), "{}", l.occupancy[dv]);
        assert!(cell(l.occupancy[p0], 1.0));
    }
}

/// Paper Table VII — π -O2 for Skylake: footer; the 4.25-vs-4.00
/// uniform-split overhang.
#[test]
fn table7_footer() {
    let m = skylake();
    let a = analyze(&workloads::find("pi", "skl", "-O2").unwrap().kernel(), &m).unwrap();
    let want: &[(&str, f32)] = &[
        ("P0", 4.25),
        ("0DV", 4.0),
        ("P1", 3.25),
        ("P5", 1.75),
        ("P6", 0.75),
        ("P7", 0.0),
    ];
    for (p, v) in want {
        let i = m.port_index(p).unwrap();
        assert!(cell(a.totals[i], *v), "{p}: {} want {v}", a.totals[i]);
    }
    assert_eq!(a.bottleneck_port, m.port_index("P0").unwrap());
    assert!(cell(a.cy_per_asm_iter, 4.25));
}

/// Paper Table I, all six rows (predictions only; measurements are in
/// the simulator integration test).
#[test]
fn table1_rows() {
    let coord = Coordinator::cpu_only();
    let rows = table1(&coord).unwrap();
    assert_eq!(rows.len(), 6);
    for r in &rows {
        assert!(cell(r.osaca_skl, 2.0), "{r:?}");
        let zen_want = if r.compiled_for == "skl" && r.flag == "-O3" { 4.0 } else { 2.0 };
        assert!(cell(r.osaca_zen, zen_want), "{r:?}");
        // IACA-like: pure port binding 2.0 (paper: 2.00-2.24).
        assert!(r.iaca_skl > 1.9 && r.iaca_skl < 2.3, "{r:?}");
    }
}

/// π on Zen: OSACA predicts 4.00 at -O1/-O2 and 2.00/it at -O3
/// (Table V column 4).
#[test]
fn table5_zen_predictions() {
    let m = zen();
    for (flag, want_asm, unroll) in [("-O1", 4.0, 1), ("-O2", 4.0, 1), ("-O3", 16.0, 8)] {
        let w = workloads::find("pi", "zen", flag).unwrap();
        let a = analyze(&w.kernel(), &m).unwrap();
        assert!(cell(a.cy_per_asm_iter, want_asm), "{flag}: {}", a.cy_per_asm_iter);
        assert_eq!(w.unroll, unroll);
    }
}

/// π -O1 on Skylake: OSACA predicts 4.75 (Table V row 1).
#[test]
fn table5_skl_o1_prediction() {
    let a = analyze(
        &workloads::find("pi", "skl", "-O1").unwrap().kernel(),
        &skylake(),
    )
    .unwrap();
    assert!(cell(a.cy_per_asm_iter, 4.75), "{}", a.cy_per_asm_iter);
}
