//! End-to-end pinned numbers for the RISC-V (RV64) backend — the third
//! proof of the DESIGN.md §7 backend recipe. The multi-ISA frontend
//! parses the RISC-V fixtures, the `rv64` machine model resolves them,
//! and analyzer/critpath/simulator numbers are pinned. Unlike the tx2
//! tests, the triad kernel deliberately pins a *divergence*: the `rv64`
//! core is 2-wide, so the simulator is frontend-bound (4.0 cy) where
//! the uniform-split port model sees only the LS pipe (3.0 cy) — a
//! model limitation the narrow riscv-sim-style core exposes. Also pins
//! zero cross-ISA resolution-cache pollution across all three ISAs and
//! that `ibench::gen` emits valid loop kernels for every built-in
//! model (the `--learn` acceptance criterion).

use osaca::analyzer::{analyze, analyze_with, critical_path, AnalyzerConfig};
use osaca::api::{BoundKind, Engine, OsacaError, Passes};
use osaca::asm::extract_kernel_isa;
use osaca::ibench::{latency_loop, throughput_loop, BenchSpec};
use osaca::mdb::{by_name, rv64};
use osaca::report::render_occupancy;
use osaca::sim::{simulate, SimConfig};
use osaca::workloads;

fn cfg() -> SimConfig {
    SimConfig { iterations: 600, warmup: 150 }
}

fn approx(a: f32, b: f32) -> bool {
    (a - b).abs() < 0.011
}

/// Triad, scalar RV64GC: 2 loads + 1 store AGU on the single LS pipe
/// -> 3.0 cy per assembly iteration for the port model (unroll 1).
#[test]
fn triad_rv64_analyzer_pinned() {
    let w = workloads::find("triad", "rv64", "-O2").unwrap();
    let m = rv64();
    let a = analyze(&w.kernel(), &m).unwrap();
    assert!(approx(a.cy_per_asm_iter, 3.0), "{}", a.cy_per_asm_iter);
    assert_eq!(m.ports[a.bottleneck_port], "LS");
    let want: &[(&str, f32)] = &[
        ("LS", 3.0),
        ("SD", 1.0),
        ("F", 1.0),
        ("I0", 1.5),
        ("I1", 1.5),
        ("B", 1.0),
        ("DV", 0.0),
    ];
    for (port, v) in want {
        let p = m.port_index(port).unwrap();
        assert!(approx(a.totals[p], *v), "{port}: {} want {}", a.totals[p], v);
    }
    // RISC-V branches are compare-and-branch: the bne row is NOT blank
    // (one µ-op on the B pipe), unlike fused x86 jcc / AArch64 b.ne.
    let bne = a.lines.last().unwrap();
    let b = m.port_index("B").unwrap();
    assert!(approx(bne.occupancy[b], 1.0), "{}", bne.occupancy[b]);
}

/// Triad latency structure: no loop-carried FP chain (fa4 is re-loaded
/// every iteration), so the carried bound is the 1-cycle pointer-bump
/// chain; intra-iteration chain is load(3) + fmadd(5) + store-data(1).
#[test]
fn triad_rv64_critpath_pinned() {
    let w = workloads::find("triad", "rv64", "-O2").unwrap();
    let r = critical_path(&w.kernel(), &rv64()).unwrap();
    assert!((r.carried_per_iteration - 1.0).abs() < 1e-3, "{r:?}");
    assert!((r.intra_iteration - 9.0).abs() < 1e-3, "{r:?}");
}

/// Simulated triad: the defining rv64 pin. The dual-issue frontend (8
/// slots / 2-wide = 4.0 cy) beats the LS port bound (3.0 cy) — the
/// uniform-split analyzer has no frontend model, so this is a real,
/// designed analyzer-vs-simulator gap on narrow cores (DESIGN.md §7).
#[test]
fn triad_rv64_simulated_frontend_bound() {
    let w = workloads::find("triad", "rv64", "-O2").unwrap();
    let m = rv64();
    let meas = simulate(&w.kernel(), &m, cfg()).unwrap();
    assert!(
        (3.95..4.15).contains(&meas.cycles_per_iteration),
        "{}",
        meas.cycles_per_iteration
    );
    assert_eq!(meas.counters.forwarded_loads, 0);
    // The LS pipe runs at 3 busy cycles/iter — under the 4-cycle
    // frontend period, confirming the bottleneck really is the width.
    let ls = m.port_index("LS").unwrap();
    let busy_per_iter = meas.port_busy[ls] as f64 / meas.iterations as f64;
    assert!((2.9..3.1).contains(&busy_per_iter), "{busy_per_iter}");
    let a = analyze(&w.kernel(), &m).unwrap();
    assert!(
        meas.cycles_per_iteration > a.cy_per_asm_iter as f64 + 0.8,
        "sim {} should exceed the port-model {} on the 2-wide core",
        meas.cycles_per_iteration,
        a.cy_per_asm_iter
    );
}

/// ISSUE-5 tentpole pin — the closed blind spot. With
/// `.frontend_bound(true)` the triad prediction is 4.0 cy and
/// frontend-bound (8 slots / 2-wide), matching the simulator; with the
/// flag off (the default) it stays the 3.0 cy LS-bound port prediction.
/// The port table itself is identical either way.
#[test]
fn triad_rv64_frontend_bound_closes_divergence() {
    let engine = Engine::cpu_only();
    let w = workloads::find("triad", "rv64", "-O2").unwrap();
    let request = |frontend: bool| {
        Engine::request(&w.name())
            .arch("rv64")
            .source(w.source)
            .passes(Passes::THROUGHPUT | Passes::CRITPATH)
            .frontend_bound(frontend)
    };

    let on = engine.analyze(&request(true)).unwrap();
    let t = on.throughput.as_ref().unwrap();
    // Port table untouched: LS stays the 3.0 cy port bottleneck.
    assert!(approx(t.cy_per_asm_iter, 3.0), "{}", t.cy_per_asm_iter);
    let f = t.frontend.as_ref().expect("frontend bound requested");
    assert_eq!(f.slots, 8, "8 instructions, nothing fuses on RISC-V");
    assert_eq!(f.width, 2);
    assert!((f.cy_per_asm_iter - 4.0).abs() < 1e-6);
    // The prediction now says *frontend*, at the simulator's number.
    let p = on.prediction();
    let winner = p.winner().unwrap();
    assert_eq!(winner.kind, BoundKind::FrontEnd);
    assert!((winner.cy_per_asm_iter - 4.0).abs() < 1e-6);
    assert_eq!(winner.resource, "8 slots / 2-wide");
    assert!((on.predicted_cy_per_asm_iter().unwrap() - 4.0).abs() < 1e-6);
    let meas = simulate(&w.kernel(), &rv64(), cfg()).unwrap();
    assert!(
        (meas.cycles_per_iteration - on.predicted_cy_per_asm_iter().unwrap() as f64).abs() < 0.15,
        "analyzer {} vs sim {}",
        on.predicted_cy_per_asm_iter().unwrap(),
        meas.cycles_per_iteration
    );
    // The simulator names the same resource in the Bound vocabulary.
    assert_eq!(meas.bottleneck_resource(&rv64()), "8 slots / 2-wide");

    // Flag off: the paper-style LS-bound 3.0 cy prediction survives.
    let off = engine.analyze(&request(false)).unwrap();
    assert!(off.throughput.as_ref().unwrap().frontend.is_none());
    let p = off.prediction();
    let winner = p.winner().unwrap();
    assert_eq!(winner.kind, BoundKind::PortPressure);
    assert_eq!(winner.resource, "LS");
    assert!((winner.cy_per_asm_iter - 3.0).abs() < 1e-6);
    assert!((off.predicted_cy_per_asm_iter().unwrap() - 3.0).abs() < 1e-6);
}

/// ISSUE-5 satellite: the paper-pinned skl/zen/tx2 analyzer tables are
/// bit-identical with the frontend flag off — and even with it *on*,
/// the occupancy table (totals, bottleneck, rendered text) does not
/// move; only the extra bound appears.
#[test]
fn paper_tables_bit_identical_with_frontend_flag_off() {
    let engine = Engine::cpu_only();
    for (arch, flag) in [("skl", "-O3"), ("zen", "-O3"), ("tx2", "-O2")] {
        let w = workloads::find("triad", arch, flag).unwrap();
        let m = by_name(arch).unwrap();
        let base = analyze(&w.kernel(), &m).unwrap();
        let base_table = render_occupancy(&base, &m);
        // analyze_with(flag on) renders the identical table.
        let on = analyze_with(&w.kernel(), &m, &AnalyzerConfig { frontend_bound: true }).unwrap();
        assert_eq!(render_occupancy(&on, &m), base_table, "{arch}: table moved");
        assert_eq!(on.totals, base.totals, "{arch}: totals moved");
        assert_eq!(on.cy_per_asm_iter, base.cy_per_asm_iter, "{arch}");
        assert_eq!(on.bottleneck_port, base.bottleneck_port, "{arch}");
        // The engine's default (flag off) text report embeds that exact
        // table and carries no frontend section.
        let r = engine
            .analyze(
                &Engine::request(&w.name()).arch(arch).source(w.source).passes(Passes::THROUGHPUT),
            )
            .unwrap();
        assert!(r.throughput.as_ref().unwrap().frontend.is_none(), "{arch}");
        assert!(r.to_text().contains(&base_table), "{arch}: text layout changed");
    }
}

/// π at -O1: the non-pipelined divide (DV busy 12 cy) dominates the
/// 7-cycle F-pipe pressure and the 5-cycle sum recurrence.
#[test]
fn pi_rv64_analyzer_divider_bound() {
    let w = workloads::find("pi", "rv64", "-O1").unwrap();
    let m = rv64();
    let a = analyze(&w.kernel(), &m).unwrap();
    assert!(approx(a.cy_per_asm_iter, 12.0), "{}", a.cy_per_asm_iter);
    assert_eq!(m.ports[a.bottleneck_port], "DV");
    let f = m.port_index("F").unwrap();
    assert!(approx(a.totals[f], 7.0), "F: {}", a.totals[f]);
}

/// π latency structure: the sum recurrence (fadd.d, 5 cy) is the
/// carried bound; the in-iteration chain threads fcvt(4), four 5-cycle
/// FP ops, the 20-cycle divide and the final 5-cycle add = 49 cy.
#[test]
fn pi_rv64_critpath_pinned() {
    let w = workloads::find("pi", "rv64", "-O1").unwrap();
    let r = critical_path(&w.kernel(), &rv64()).unwrap();
    assert!((r.carried_per_iteration - 5.0).abs() < 1e-3, "{r:?}");
    assert!((r.intra_iteration - 49.0).abs() < 1e-3, "{r:?}");
}

/// π through the structured prediction: the divider is a *named* bound
/// kind now — DV 12.0 beats the F-pipe pressure (7.0), the frontend
/// (9 slots / 2-wide = 4.5) and the sum recurrence (5.0), and the
/// winner says so.
#[test]
fn pi_rv64_prediction_is_divider_bound() {
    let engine = Engine::cpu_only();
    let w = workloads::find("pi", "rv64", "-O1").unwrap();
    let r = engine
        .analyze(
            &Engine::request(&w.name())
                .arch("rv64")
                .source(w.source)
                .passes(Passes::THROUGHPUT | Passes::CRITPATH)
                .frontend_bound(true),
        )
        .unwrap();
    let p = r.prediction();
    let winner = p.winner().unwrap();
    assert_eq!(winner.kind, BoundKind::Divider);
    assert_eq!(winner.resource, "DV");
    assert!((winner.cy_per_asm_iter - 12.0).abs() < 0.011);
    let port = p.bound(BoundKind::PortPressure).unwrap();
    assert_eq!(port.resource, "F");
    assert!((port.cy_per_asm_iter - 7.0).abs() < 0.011);
    let fe = p.bound(BoundKind::FrontEnd).unwrap();
    assert!((fe.cy_per_asm_iter - 4.5).abs() < 1e-6);
    let cp = p.bound(BoundKind::CriticalPath).unwrap();
    assert!((cp.cy_per_asm_iter - 5.0).abs() < 1e-3);
}

/// Simulated π: divider-serialized at ~12 cy/iter (Table V's shape on
/// the third ISA); analyzer and simulator agree here because the
/// divider period is far above the 4.5-cycle frontend period.
#[test]
fn pi_rv64_simulated() {
    let w = workloads::find("pi", "rv64", "-O1").unwrap();
    let meas = simulate(&w.kernel(), &rv64(), cfg()).unwrap();
    assert!(
        (11.8..12.3).contains(&meas.cycles_per_iteration),
        "{}",
        meas.cycles_per_iteration
    );
    assert_eq!(meas.counters.forwarded_loads, 0);
}

/// The whole Engine pipeline works on a RISC-V request: `.arch("rv64")`
/// selects the RISC-V syntax automatically, and throughput + critpath
/// + simulate all run from one decode.
#[test]
fn engine_end_to_end_rv64() {
    let engine = Engine::cpu_only();
    let w = workloads::find("triad", "rv64", "-O2").unwrap();
    let req = Engine::request(&w.name())
        .arch("rv64")
        .source(w.source)
        .passes(Passes::THROUGHPUT | Passes::CRITPATH | Passes::SIMULATE)
        .unroll(w.unroll)
        .sim_config(cfg());
    let report = engine.analyze(&req).unwrap();
    let t = report.throughput.as_ref().unwrap();
    assert!(approx(t.cy_per_asm_iter, 3.0), "{}", t.cy_per_asm_iter);
    assert!(report.critpath.is_some());
    let sim = report.simulation.as_ref().unwrap();
    assert!((3.95..4.15).contains(&sim.cycles_per_iteration), "{}", sim.cycles_per_iteration);
    assert!(approx(report.predicted_cy_per_asm_iter().unwrap(), 3.0));
    let json = report.to_json();
    assert!(json.contains("\"arch\":\"rv64\""));
    assert!(json.contains("\"throughput\""));
    assert!(json.contains("\"simulation\""));
}

/// The engine lists rv64 among the available architectures and rejects
/// ISA-mismatched requests with a structured error — in both foreign
/// directions (x86 and AArch64 kernels).
#[test]
fn isa_mismatch_is_structured() {
    let engine = Engine::cpu_only();
    assert!(engine.available_arches().contains(&"rv64".to_string()));
    let xk = workloads::find("triad", "skl", "-O3").unwrap().kernel();
    let req = Engine::request("mismatch").arch("rv64").kernel(xk);
    match engine.analyze(&req) {
        Err(OsacaError::IsaMismatch { kernel_isa, model_isa, arch }) => {
            assert_eq!(kernel_isa, "x86");
            assert_eq!(model_isa, "riscv");
            assert_eq!(arch, "rv64");
        }
        other => panic!("expected IsaMismatch, got {other:?}"),
    }
    let ak = workloads::find("triad", "tx2", "-O2").unwrap().kernel();
    let req = Engine::request("mismatch2").arch("rv64").kernel(ak);
    match engine.analyze(&req) {
        Err(OsacaError::IsaMismatch { kernel_isa, model_isa, .. }) => {
            assert_eq!(kernel_isa, "aarch64");
            assert_eq!(model_isa, "riscv");
        }
        other => panic!("expected IsaMismatch, got {other:?}"),
    }
    // A RISC-V kernel against an x86 model is the reverse mismatch.
    let rk = workloads::find("pi", "rv64", "-O1").unwrap().kernel();
    let req = Engine::request("mismatch3").arch("skl").kernel(rk);
    assert!(matches!(engine.analyze(&req), Err(OsacaError::IsaMismatch { .. })));
}

/// Every RISC-V branch resolves against the database (nothing fuses),
/// so an unmodeled branch form is a structured UnresolvedForm at
/// prepare time, and a modeled one is charged on the B pipe by
/// analyzer and simulator alike.
#[test]
fn compare_branch_validation_is_structured() {
    let engine = Engine::cpu_only();
    // bltz has no rv64 entry — prepare() must catch it.
    let req = Engine::request("cb")
        .arch("rv64")
        .source("\n.L1:\naddi a4, a4, 1\nbltz a4, .L1\n")
        .passes(Passes::THROUGHPUT | Passes::SIMULATE);
    match engine.analyze(&req) {
        Err(OsacaError::UnresolvedForm { form, arch, .. }) => {
            assert!(form.contains("bltz"), "{form}");
            assert_eq!(arch, "rv64");
        }
        other => panic!("expected UnresolvedForm, got {other:?}"),
    }
    // The modeled bne form runs end to end: addi + bne = 2 slots on
    // the 2-wide frontend = 1 cy/iter, with the B pipe at 1.0.
    let req = Engine::request("cb2")
        .arch("rv64")
        .source("\n.L1:\naddi a4, a4, 1\nbne a4, a5, .L1\n")
        .passes(Passes::THROUGHPUT | Passes::SIMULATE)
        .sim_config(cfg());
    let report = engine.analyze(&req).unwrap();
    let t = report.throughput.as_ref().unwrap();
    assert!(approx(t.cy_per_asm_iter, 1.0), "{}", t.cy_per_asm_iter);
    let sim = report.simulation.as_ref().unwrap();
    assert!((0.95..1.15).contains(&sim.cycles_per_iteration), "{}", sim.cycles_per_iteration);
}

/// Cross-ISA cache hygiene across all three ISAs: warm analyses
/// perform zero fresh form resolutions, RISC-V forms are direct hits
/// only (no synthesis tier exists for the ISA), and foreign-ISA
/// instructions are rejected by every other model.
#[test]
fn form_index_has_no_cross_isa_pollution() {
    let skl = by_name("skl").unwrap();
    let tx2 = by_name("tx2").unwrap();
    let rv = by_name("rv64").unwrap();
    let xk = workloads::find("triad", "skl", "-O3").unwrap().kernel();
    let ak = workloads::find("triad", "tx2", "-O2").unwrap().kernel();
    let rk = workloads::find("triad", "rv64", "-O2").unwrap().kernel();
    let sim_cfg = SimConfig { iterations: 60, warmup: 15 };
    analyze(&xk, &skl).unwrap();
    analyze(&ak, &tx2).unwrap();
    analyze(&rk, &rv).unwrap();
    simulate(&rk, &rv, sim_cfg).unwrap();
    let skl_misses = skl.resolution_miss_count();
    let rv_misses = rv.resolution_miss_count();
    // The RISC-V fixture resolves entirely from direct entries.
    assert_eq!(rv_misses, 0, "RISC-V forms must be direct hits");
    for _ in 0..3 {
        analyze(&xk, &skl).unwrap();
        analyze(&rk, &rv).unwrap();
        simulate(&rk, &rv, sim_cfg).unwrap();
    }
    assert_eq!(skl.resolution_miss_count(), skl_misses, "x86 misses moved");
    assert_eq!(rv.resolution_miss_count(), rv_misses, "RISC-V misses moved");
    // Foreign-ISA instructions are rejected in every direction.
    assert!(rv.resolve(&xk.instructions[0]).is_err());
    assert!(rv.resolve(&ak.instructions[0]).is_err());
    assert!(skl.resolve(&rk.instructions[0]).is_err());
    assert!(tx2.resolve(&rk.instructions[0]).is_err());
    assert_eq!(rv.resolution_miss_count(), rv_misses);
}

/// ISSUE-4 acceptance: `ibench::gen` emits valid loop kernels for all
/// built-in models — every generated instruction parses under the
/// model's syntax, resolves against its database, and the loop
/// simulates. (The x86-only bail in `builder` is gone; this is the
/// generator-level half of that guarantee.)
#[test]
fn ibench_emits_valid_kernels_for_every_builtin_model() {
    // (model, representative ALU form, load form)
    let cases: &[(&str, &str, &str)] = &[
        ("skl", "vaddpd-xmm_xmm_xmm", "vmovapd-mem_xmm"),
        ("zen", "vmulpd-xmm_xmm_xmm", "vmovapd-mem_xmm"),
        ("hsw", "vaddpd-xmm_xmm_xmm", "vmovapd-mem_xmm"),
        ("tx2", "fadd-d_d_d", "ldr-d_mem"),
        ("rv64", "fadd.d-f_f_f", "fld-f_mem"),
    ];
    for (arch, alu, load) in cases {
        let m = by_name(arch).unwrap();
        for (label, src) in [
            ("lat", latency_loop(&BenchSpec::parse(alu), m.isa, 4).unwrap()),
            ("tp", throughput_loop(&BenchSpec::parse(alu), m.isa, 8).unwrap()),
            ("load-tp", throughput_loop(&BenchSpec::parse(load), m.isa, 4).unwrap()),
        ] {
            let k = extract_kernel_isa(&format!("{arch}-{label}"), &src, m.isa)
                .unwrap_or_else(|e| panic!("{arch}/{label}: {e}"));
            assert_eq!(k.isa, m.isa, "{arch}/{label}");
            // Every non-fusible instruction resolves against the model.
            for ins in &k.instructions {
                if ins.is_fusible_branch() {
                    continue;
                }
                m.resolve(ins).unwrap_or_else(|e| panic!("{arch}/{label}: {e}"));
            }
            let meas = simulate(&k, &m, SimConfig { iterations: 50, warmup: 10 })
                .unwrap_or_else(|e| panic!("{arch}/{label}: {e}"));
            assert!(meas.cycles_per_iteration > 0.0, "{arch}/{label}");
        }
    }
}
