//! Integration: the AOT artifact (JAX/Pallas → HLO text → PJRT) against
//! the pure-rust reference solver. Requires `make artifacts`; tests
//! skip with a notice when the artifact has not been built.

use osaca::baseline::{encode, predict, predict_batch, predict_cpu};
use osaca::mdb::{skylake, zen};
use osaca::runtime::{solve_cpu, EncodedKernel, PortSolver, BATCH};
use osaca::workloads;

fn solver() -> Option<PortSolver> {
    match PortSolver::load_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("SKIP: {e}");
            None
        }
    }
}

#[test]
fn artifact_matches_cpu_solver_on_workloads() {
    let Some(s) = solver() else { return };
    let m = skylake();
    for w in workloads::all() {
        let k = w.kernel();
        let enc = encode(&k, &m).unwrap();
        let pjrt = s.solve(&[enc.clone()]).unwrap();
        let cpu = solve_cpu(&[enc], 32);
        assert!(
            (pjrt[0].tp_uniform - cpu[0].tp_uniform).abs() < 1e-4,
            "{}: uniform {} vs {}",
            w.name(),
            pjrt[0].tp_uniform,
            cpu[0].tp_uniform
        );
        assert!(
            (pjrt[0].tp_balanced - cpu[0].tp_balanced).abs() < 1e-3,
            "{}: balanced {} vs {}",
            w.name(),
            pjrt[0].tp_balanced,
            cpu[0].tp_balanced
        );
        for (a, b) in pjrt[0].press_uniform.iter().zip(cpu[0].press_uniform.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }
}

#[test]
fn artifact_batch_solves_full_batch() {
    let Some(s) = solver() else { return };
    let m = zen();
    let kernels: Vec<_> = workloads::all().iter().map(|w| w.kernel()).collect();
    let refs: Vec<&_> = kernels.iter().take(BATCH).collect();
    let preds = predict_batch(&refs, &m, &s).unwrap();
    assert_eq!(preds.len(), refs.len());
    for (w, p) in workloads::all().iter().zip(preds.iter()) {
        let cpu = predict_cpu(&w.kernel(), &m).unwrap();
        assert!(
            (p.cy_per_asm_iter - cpu.cy_per_asm_iter).abs() < 1e-3,
            "{}: {} vs {}",
            w.name(),
            p.cy_per_asm_iter,
            cpu.cy_per_asm_iter
        );
    }
}

#[test]
fn artifact_pi_o2_prediction() {
    let Some(s) = solver() else { return };
    let m = skylake();
    let w = workloads::find("pi", "skl", "-O2").unwrap();
    let p = predict(&w.kernel(), &m, &s).unwrap();
    // The IACA-like 4.00 cy of §III-B through the real PJRT path.
    assert!((p.cy_per_asm_iter - 4.0).abs() < 0.1, "{}", p.cy_per_asm_iter);
}

#[test]
fn critpath_artifact_matches_rust_analyzer() {
    use osaca::analyzer::critpath::{critical_path_batch, encode_graph};
    use osaca::analyzer::critical_path;
    use osaca::runtime::CritSolver;
    let solver = match CritSolver::load_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    for machine in [skylake(), zen()] {
        let kernels: Vec<_> = workloads::all().iter().map(|w| w.kernel()).collect();
        for chunk in kernels.chunks(BATCH) {
            let refs: Vec<&_> = chunk.iter().collect();
            let batch = critical_path_batch(&refs, &machine, &solver).unwrap();
            for (k, out) in chunk.iter().zip(batch.iter()) {
                let exact = critical_path(k, &machine).unwrap();
                assert!(
                    (out.carried_bound - exact.carried_per_iteration).abs() < 1e-2,
                    "{} {}: artifact {} vs analyzer {}",
                    machine.name,
                    k.name,
                    out.carried_bound,
                    exact.carried_per_iteration
                );
                assert!(
                    (out.intra - exact.intra_iteration).abs() < 1e-2,
                    "{} {}: intra {} vs {}",
                    machine.name,
                    k.name,
                    out.intra,
                    exact.intra_iteration
                );
                // Sanity: the encoder produces a graph (non-trivial lat).
                let g = encode_graph(k, &machine).unwrap();
                assert!(g.lat.iter().any(|&l| l > 0.0));
            }
        }
    }
}

#[test]
fn critpath_artifact_pi_o1_bound() {
    use osaca::analyzer::critpath::critical_path_batch;
    use osaca::runtime::CritSolver;
    let solver = match CritSolver::load_default() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP: {e}");
            return;
        }
    };
    let w = workloads::find("pi", "skl", "-O1").unwrap();
    let k = w.kernel();
    let out = critical_path_batch(&[&k], &skylake(), &solver).unwrap();
    // The §III-B anomaly: 9 cy/it store-forwarding chain, via PJRT.
    assert!((out[0].carried_bound - 9.0).abs() < 0.05, "{}", out[0].carried_bound);
}

#[test]
fn oversize_batch_is_rejected() {
    let Some(s) = solver() else { return };
    let encs: Vec<EncodedKernel> = (0..BATCH + 1).map(|_| EncodedKernel::empty()).collect();
    assert!(s.solve(&encs).is_err());
}

#[test]
fn empty_batch_is_fine() {
    let Some(s) = solver() else { return };
    let out = s.solve(&[]).unwrap();
    assert!(out.is_empty());
}
