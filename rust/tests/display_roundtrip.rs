//! Parse → display → parse round-trip over every shipped workload
//! fixture (all three ISAs). PR 2 removed `Instruction.raw` and made
//! `Display` reconstruct source lines; this pins that the
//! reconstruction is faithful: re-parsing the rendered text yields an
//! identical instruction (mnemonic, operands, prefixes, ISA), and the
//! rendering is a canonical fixpoint (display∘parse∘display = display).

use osaca::asm::{parse_file_isa, parse_instruction_isa, Line};
use osaca::workloads;

#[test]
fn every_fixture_roundtrips_through_display() {
    for w in workloads::all_isa() {
        let lines = parse_file_isa(w.source, w.isa).unwrap_or_else(|e| panic!("{}: {e}", w.name()));
        let mut checked = 0usize;
        for l in &lines {
            let Line::Instruction(i) = l else { continue };
            let text = i.to_string();
            let re = parse_instruction_isa(&text, i.line, w.isa)
                .unwrap_or_else(|e| panic!("{}: reparse of `{text}`: {e}", w.name()));
            assert_eq!(&re, i, "{}: `{text}`", w.name());
            assert_eq!(re.to_string(), text, "{}: display not a fixpoint", w.name());
            checked += 1;
        }
        assert!(checked >= 5, "{}: only {checked} instructions checked", w.name());
    }
}

#[test]
fn extracted_kernels_roundtrip_through_display() {
    // Kernel extraction preserves the same instructions, so the
    // round-trip must also hold on what analyses actually consume.
    for w in workloads::all_isa() {
        let k = w.kernel();
        for i in &k.instructions {
            let text = i.to_string();
            let re = parse_instruction_isa(&text, i.line, w.isa)
                .unwrap_or_else(|e| panic!("{}: `{text}`: {e}", w.name()));
            assert_eq!(&re, i, "{}: `{text}`", w.name());
        }
    }
}

/// Constructs PR 2's known risk spots explicitly: prefixes, memory
/// operand shapes (zero displacement, scale 1, missing base, segment
/// overrides, rip-relative symbols), case-folded mnemonics.
#[test]
fn tricky_x86_spellings_roundtrip() {
    use osaca::isa::Isa;
    for src in [
        "lock addl $1, (%rax)",
        "vfmadd132pd 0(%r13,%rax), %ymm3, %ymm0",
        "vmovsd -8(%rcx,%rax,8), %xmm0",
        "vmovsd .LC2(%rip), %xmm4",
        "movq %fs:16(%rax), %rbx",
        "movl (,%rax,4), %ebx",
        "VADDPD %Ymm1, %ymm2, %YMM3",
        "addq $-32, %rax",
        "vextracti128 $0x1, %ymm2, %xmm1",
        "jne .L2",
    ] {
        let i = parse_instruction_isa(src, 7, Isa::X86).unwrap_or_else(|e| panic!("{src}: {e}"));
        let text = i.to_string();
        let re = parse_instruction_isa(&text, 7, Isa::X86)
            .unwrap_or_else(|e| panic!("reparse `{text}`: {e}"));
        assert_eq!(re, i, "{src} -> {text}");
        assert_eq!(re.to_string(), text, "{src}: not a fixpoint");
    }
}

#[test]
fn tricky_riscv_spellings_roundtrip() {
    use osaca::isa::Isa;
    for src in [
        "fld fa5, 0(a5)",
        "fsd fa4, -8(a3)",
        "ld a0, 16(sp)",
        "sd ra, 8(sp)",
        "fmadd.d fa4, fa3, fa0, fa4",
        "fdiv.d fa4, fa0, fa4",
        "fcvt.d.w fa5, a4",
        "addi a5, a5, 8",
        "addiw a4, a4, 1",
        "xor a3, a3, a3",
        "mv a0, a1",
        "li t0, 111",
        "bne a4, a5, .L2",
        "j .L5",
    ] {
        let i = parse_instruction_isa(src, 5, Isa::RiscV).unwrap_or_else(|e| panic!("{src}: {e}"));
        let text = i.to_string();
        assert_eq!(text, src, "canonical rendering differs");
        let re = parse_instruction_isa(&text, 5, Isa::RiscV)
            .unwrap_or_else(|e| panic!("reparse `{text}`: {e}"));
        assert_eq!(re, i, "{src} -> {text}");
        assert_eq!(re.to_string(), text, "{src}: not a fixpoint");
    }
    // Raw architectural spellings are preserved, and a zero-offset
    // `(base)` canonicalizes to `0(base)`.
    let i = parse_instruction_isa("ld x10, (x15)", 1, Isa::RiscV).unwrap();
    assert_eq!(i.to_string(), "ld x10, 0(x15)");
    let re = parse_instruction_isa(&i.to_string(), 1, Isa::RiscV).unwrap();
    assert_eq!(re, i);
}

#[test]
fn all_three_isas_have_fixture_coverage() {
    // The 16+ fixture set spans all three ISAs; the blanket round-trip
    // tests above only prove what the fixture list feeds them.
    use osaca::isa::Isa;
    let ws = workloads::all_isa();
    assert!(ws.len() >= 16, "{} fixtures", ws.len());
    for isa in [Isa::X86, Isa::AArch64, Isa::RiscV] {
        assert!(ws.iter().any(|w| w.isa == isa), "no fixture for {isa}");
    }
}

#[test]
fn tricky_aarch64_spellings_roundtrip() {
    use osaca::isa::Isa;
    for src in [
        "ldr q0, [x7, x4]",
        "ldr d1, [x2, x5, lsl #3]",
        "str w0, [sp, #16]",
        "fmla v0.2d, v1.2d, v2.2d",
        "eor v3.16b, v3.16b, v3.16b",
        "movi v0.2d, #0",
        "subs x5, x5, #2",
        "mov x1, #111",
        "b.ne .L4",
        "ldr x0, [x1]",
    ] {
        let i = parse_instruction_isa(src, 3, Isa::AArch64).unwrap_or_else(|e| panic!("{src}: {e}"));
        let text = i.to_string();
        let re = parse_instruction_isa(&text, 3, Isa::AArch64)
            .unwrap_or_else(|e| panic!("reparse `{text}`: {e}"));
        assert_eq!(re, i, "{src} -> {text}");
        assert_eq!(re.to_string(), text, "{src}: not a fixpoint");
    }
}
