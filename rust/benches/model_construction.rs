//! Bench: the §II-A/§II-C model-construction pipeline — latency and
//! throughput benchmarking plus conflict probing on the simulator
//! substrate (the paper's ibench listings, regenerated).
//!
//! Run: `cargo bench --bench model_construction`

use osaca::benchlib::{bench, print_table};
use osaca::builder::{default_probes, infer_entry};
use osaca::ibench::{run_conflict, run_sweep, BenchSpec};
use osaca::isa::InstructionForm;
use osaca::mdb;

fn main() {
    // §II-C listings for both machines.
    for arch in ["zen", "skl"] {
        let machine = mdb::by_name(arch).unwrap();
        let spec = BenchSpec::parse("vfmadd132pd-mem_xmm_xmm");
        let sweep = run_sweep(&spec, &machine).unwrap();
        println!("--- {} ---", machine.arch_name);
        print!("{}", sweep.render(machine.frequency_ghz));
        for probe in ["vaddpd-xmm_xmm_xmm", "vmulpd-xmm_xmm_xmm"] {
            let r = run_conflict(&spec, &BenchSpec::parse(probe), &machine).unwrap();
            println!("{}:  {:.3} (clk cy)", r.label, r.cy_per_instr);
        }
        println!();
    }

    // §II-A vaddpd numbers as a table.
    let mut rows = Vec::new();
    for arch in ["skl", "zen"] {
        let machine = mdb::by_name(arch).unwrap();
        let spec = BenchSpec::parse("vaddpd-xmm_xmm_xmm");
        let lat = osaca::ibench::measure_latency(&spec, &machine).unwrap();
        let tp = osaca::ibench::measure_throughput(&spec, &machine).unwrap();
        rows.push(vec![
            machine.arch_name.clone(),
            format!("{lat:.2}"),
            format!("{tp:.3}"),
        ]);
    }
    print_table("§II-A vaddpd (paper: lat 4/3 cy, rTP 0.5)", &["arch", "latency", "rTP"], &rows);

    // Timings.
    let zen = mdb::zen();
    let probes = default_probes(&zen);
    let form = InstructionForm::parse("vfmadd132pd-mem_xmm_xmm");
    let s = bench("ibench/sweep (7 benchmarks on sim)", 1, 5, || {
        run_sweep(&BenchSpec { form: form.clone() }, &zen).unwrap();
    });
    println!("{}", s.report());
    let s = bench("builder/infer_entry (sweep + conflict probes)", 1, 5, || {
        infer_entry(&form, &zen, &probes).unwrap();
    });
    println!("{}", s.report());
}
