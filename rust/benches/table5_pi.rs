//! Bench: regenerate paper Table V (π benchmark) together with the
//! §III-B stall-counter investigation, and time the full comparison.
//!
//! Run: `cargo bench --bench table5_pi`

use osaca::benchlib::{bench, print_table};
use osaca::coordinator::Coordinator;
use osaca::mdb;
use osaca::report::experiments::{render_table5, table5};
use osaca::sim::{simulate, SimConfig};
use osaca::workloads;

fn main() {
    let coord = Coordinator::auto();
    let cfg = SimConfig::default();
    let rows = table5(&coord, cfg).expect("table5");
    print_table(
        "Table V: pi benchmark predictions vs measurement",
        &["arch", "flag", "IACA-like", "OSACA", "measured cy/it", "stall cy"],
        &render_table5(&rows),
    );

    // The §III-B counter factors (paper: 17x on SKL, 7x on Zen).
    let mut counter_rows = Vec::new();
    for arch in ["skl", "zen"] {
        let m = mdb::by_name(arch).unwrap();
        let stall = |flag: &str| {
            let w = workloads::find("pi", arch, flag).unwrap();
            let meas = simulate(&w.kernel(), &m, cfg).unwrap();
            meas.counters.issue_stall_cycles as f64 / meas.window_cycles as f64
        };
        let s1 = stall("-O1");
        let s2 = stall("-O2");
        counter_rows.push(vec![
            m.arch_name.clone(),
            format!("{:.1}%", s1 * 100.0),
            format!("{:.1}%", s2 * 100.0),
            format!("{:.1}x", s1 / s2.max(1e-9)),
        ]);
    }
    print_table(
        "issue-stall fractions, -O1 vs -O2 (the §III-B investigation)",
        &["arch", "-O1 stalls", "-O2 stalls", "factor"],
        &counter_rows,
    );

    let s = bench("table5/full-regeneration", 1, 5, || {
        table5(&coord, SimConfig { iterations: 400, warmup: 100 }).unwrap();
    });
    println!("{}", s.report());
}
