//! Ablations over the simulator's microarchitectural parameters and the
//! model's special-case rules — the design choices DESIGN.md calls out.
//!
//! Each ablation flips ONE thing and reruns the paper's kernels:
//!  * zero-idiom elimination off  -> -O2 π slows to the model's 4.25;
//!  * divider scale 1.0 on Zen    -> the §III-B 20% gap disappears;
//!  * rename width sweep          -> frontend-bound kernels degrade;
//!  * ROB size sweep              -> the -O1 forwarding chain is ROB-
//!    insensitive (latency-bound), triad is not;
//!  * hide-load-behind-store off  -> Zen triad prediction inflates.
//!
//! Run: `cargo bench --bench ablations`

use osaca::analyzer::analyze;
use osaca::benchlib::print_table;
use osaca::mdb;
use osaca::sim::{simulate, SimConfig};
use osaca::workloads;

fn cfg() -> SimConfig {
    SimConfig { iterations: 500, warmup: 120 }
}

fn main() {
    // --- 1. zero-idiom elimination ---------------------------------
    let w = workloads::find("pi", "skl", "-O2").unwrap();
    let k = w.kernel();
    let skl = mdb::skylake();
    let mut no_elim = skl.clone();
    no_elim.sim_zero_idiom_elim = false;
    no_elim.sim_macro_fusion = false;
    let with_elim = simulate(&k, &skl, cfg()).unwrap().cycles_per_iteration;
    let without = simulate(&k, &no_elim, cfg()).unwrap().cycles_per_iteration;
    print_table(
        "ablation: scheduler shortcuts (π -O2, SKL; model predicts 4.25)",
        &["variant", "measured cy/it"],
        &[
            vec!["zero-idiom elim + macro-fusion (hw)".into(), format!("{with_elim:.2}")],
            vec!["idiom recognition off (xor serializes the chain)".into(), format!("{without:.2}")],
        ],
    );

    // --- 2. Zen divider scale ---------------------------------------
    let wpi = workloads::find("pi", "zen", "-O2").unwrap();
    let kpi = wpi.kernel();
    let zen = mdb::zen();
    let mut ideal_div = zen.clone();
    ideal_div.params.sim_divider_scale = 1.0;
    let real = simulate(&kpi, &zen, cfg()).unwrap().cycles_per_iteration;
    let ideal = simulate(&kpi, &ideal_div, cfg()).unwrap().cycles_per_iteration;
    print_table(
        "ablation: Zen divider pipelining (π -O2; model predicts 4.00)",
        &["variant", "measured cy/it"],
        &[
            vec!["divider scale 1.25 (real Zen)".into(), format!("{real:.2}")],
            vec!["divider scale 1.00 (idealized)".into(), format!("{ideal:.2}")],
        ],
    );

    // --- 3. rename width sweep ---------------------------------------
    let wt = workloads::find("triad", "skl", "-O3").unwrap();
    let kt = wt.kernel();
    let mut rows = Vec::new();
    for width in [2, 3, 4, 6] {
        let mut m = skl.clone();
        m.params.rename_width = width;
        let cy = simulate(&kt, &m, cfg()).unwrap().cycles_per_iteration;
        rows.push(vec![format!("{width}"), format!("{cy:.2}")]);
    }
    print_table(
        "ablation: rename width (triad -O3 SKL, port bound 2.0)",
        &["rename width", "measured cy/asm-iter"],
        &rows,
    );

    // --- 4. ROB size sweep -------------------------------------------
    let wp1 = workloads::find("pi", "skl", "-O1").unwrap();
    let kp1 = wp1.kernel();
    let mut rows = Vec::new();
    for rob in [32, 64, 128, 224] {
        let mut m = skl.clone();
        m.params.rob_size = rob;
        m.params.scheduler_size = (rob / 2).min(97);
        let pi1 = simulate(&kp1, &m, cfg()).unwrap().cycles_per_iteration;
        let tri = simulate(&kt, &m, cfg()).unwrap().cycles_per_iteration;
        rows.push(vec![format!("{rob}"), format!("{pi1:.2}"), format!("{tri:.2}")]);
    }
    print_table(
        "ablation: ROB size (π -O1 is latency-bound and insensitive; triad needs in-flight loads)",
        &["ROB µops", "π -O1 cy/it", "triad -O3 cy/asm-iter"],
        &rows,
    );

    // --- 5. Zen hideable loads (analyzer-side) -----------------------
    let wz = workloads::find("triad", "zen", "-O3").unwrap();
    let kz = wz.kernel();
    let mut no_hide = zen.clone();
    no_hide.hide_load_behind_store = false;
    let with_hide = analyze(&kz, &zen).unwrap().cy_per_asm_iter;
    let without_hide = analyze(&kz, &no_hide).unwrap().cy_per_asm_iter;
    let measured = simulate(&kz, &zen, cfg()).unwrap().cycles_per_iteration;
    print_table(
        "ablation: Zen hide-load-behind-store (triad -O3 Zen, Table IV)",
        &["variant", "cy/asm-iter"],
        &[
            vec!["prediction with hiding (OSACA)".into(), format!("{with_hide:.2}")],
            vec!["prediction without hiding".into(), format!("{without_hide:.2}")],
            vec!["simulated hardware".into(), format!("{measured:.2}")],
        ],
    );
}
