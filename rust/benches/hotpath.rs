//! Bench: the hot paths of each layer, for the performance pass
//! (EXPERIMENTS.md §Perf).
//!
//! * L3 simulator: simulated Mcycles/s and µops/s on the heaviest
//!   kernels;
//! * L3 analyzer: kernels analyzed per second;
//! * L1/L2 solver: batched artifact executions per second (PJRT) vs the
//!   pure-rust reference;
//! * coordinator: end-to-end requests per second under concurrency.
//!
//! Run: `cargo bench --bench hotpath`

use std::sync::Arc;

use osaca::analyzer::analyze;
use osaca::baseline::encode;
use osaca::benchlib::{bench, Stats};
use osaca::coordinator::Coordinator;
use osaca::mdb;
use osaca::runtime::{solve_cpu, EncodedKernel, PortSolver, BATCH};
use osaca::sim::{simulate, SimConfig};
use osaca::workloads;

fn main() {
    let skl = mdb::skylake();
    let zen = mdb::zen();

    // ---- machine-model registry ---------------------------------------
    // Built-in models are parsed once per process and served from the
    // Arc cache; assert that a million lookups do not re-parse.
    println!("--- mdb registry ---");
    let parses_before = mdb::builtin_parse_count();
    let s = bench("mdb/by_name_shared/1e6-lookups", 2, 10, || {
        for _ in 0..1_000_000 {
            std::hint::black_box(mdb::by_name_shared("skl"));
        }
    });
    println!("{}  ({:.0} lookups/s)", s.report(), 1e6 / s.median.as_secs_f64());
    assert_eq!(
        mdb::builtin_parse_count(),
        parses_before,
        "cached machine-model lookups must not re-parse the embedded .mdb text"
    );

    // ---- L3 simulator -------------------------------------------------
    println!("--- L3 simulator ---");
    for (arch, m) in [("skl", &skl), ("zen", &zen)] {
        let w = workloads::find("pi", arch, "-O3").unwrap();
        let k = w.kernel();
        let cfg = SimConfig { iterations: 4000, warmup: 400 };
        let mut total_cycles = 0u64;
        let mut uops = 0u64;
        let s = bench(&format!("sim/pi-o3/{arch}"), 2, 10, || {
            let meas = simulate(&k, m, cfg).unwrap();
            total_cycles = meas.total_cycles;
            uops = meas.counters.uops_executed;
        });
        report_sim(&s, total_cycles, uops);
    }
    {
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let k = w.kernel();
        let cfg = SimConfig { iterations: 4000, warmup: 400 };
        let mut total_cycles = 0u64;
        let mut uops = 0u64;
        let s = bench("sim/triad-o3/skl", 2, 10, || {
            let meas = simulate(&k, &skl, cfg).unwrap();
            total_cycles = meas.total_cycles;
            uops = meas.counters.uops_executed;
        });
        report_sim(&s, total_cycles, uops);
    }

    // ---- L3 analyzer ---------------------------------------------------
    println!("--- L3 analyzer ---");
    let kernels: Vec<_> = workloads::all().iter().map(|w| w.kernel()).collect();
    let s = bench("analyze/all-workloads/skl", 3, 20, || {
        for k in &kernels {
            analyze(k, &skl).unwrap();
        }
    });
    println!(
        "{}  ({:.0} kernels/s)",
        s.report(),
        kernels.len() as f64 / s.median.as_secs_f64()
    );

    // ---- L1/L2 solver ---------------------------------------------------
    println!("--- L1/L2 port solver ---");
    let encs: Vec<EncodedKernel> = kernels.iter().map(|k| encode(k, &skl).unwrap()).collect();
    let batch: Vec<EncodedKernel> = encs.iter().cycle().take(BATCH).cloned().collect();
    let s = bench("solve/cpu-reference/batch8", 3, 20, || {
        solve_cpu(&batch, 32);
    });
    println!("{}  ({:.0} kernels/s)", s.report(), BATCH as f64 / s.median.as_secs_f64());
    match PortSolver::load_default() {
        Ok(solver) => {
            let s = bench("solve/pjrt-artifact/batch8", 3, 20, || {
                solver.solve(&batch).unwrap();
            });
            println!("{}  ({:.0} kernels/s)", s.report(), BATCH as f64 / s.median.as_secs_f64());
        }
        Err(e) => println!("solve/pjrt-artifact: SKIPPED ({e})"),
    }

    // ---- coordinator ----------------------------------------------------
    println!("--- coordinator ---");
    let coord = Arc::new(Coordinator::auto());
    let n = 128;
    let s = bench("coordinator/end-to-end/128-reqs", 1, 8, || {
        let mut handles = Vec::new();
        for i in 0..n {
            let coord = coord.clone();
            handles.push(std::thread::spawn(move || {
                let ws = workloads::all();
                let w = ws[i % ws.len()];
                let m = if i % 2 == 0 { mdb::skylake() } else { mdb::zen() };
                coord.analyze_kernel(&w.kernel(), &m).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    println!("{}  ({:.0} req/s)", s.report(), n as f64 / s.median.as_secs_f64());
    println!(
        "coordinator stats: {} batches, avg batch {:.2}",
        coord.stats.batches.load(std::sync::atomic::Ordering::Relaxed),
        coord.stats.avg_batch_size()
    );

    // ---- api batch path -------------------------------------------------
    // The Engine::analyze_batch fast path: one submission, direct B=8
    // slot mapping, no per-request reply channels.
    use osaca::api::{Engine, Passes};
    let engine = Engine::cpu_only();
    let ws = workloads::all();
    let reqs: Vec<_> = (0..n)
        .map(|i| {
            let w = ws[i % ws.len()];
            Engine::request(&w.name())
                .arch(if i % 2 == 0 { "skl" } else { "zen" })
                .source(w.source)
                .passes(Passes::ANALYTIC)
                .unroll(w.unroll)
        })
        .collect();
    let s = bench("api/analyze_batch/128-reqs", 1, 8, || {
        let results = engine.analyze_batch(&reqs);
        assert!(results.iter().all(|r| r.is_ok()));
    });
    println!("{}  ({:.0} req/s)", s.report(), n as f64 / s.median.as_secs_f64());
    println!(
        "engine stats: {} batches, avg batch {:.2}",
        engine.stats().batches.load(std::sync::atomic::Ordering::Relaxed),
        engine.stats().avg_batch_size()
    );
}

fn report_sim(s: &Stats, cycles: u64, uops: u64) {
    println!(
        "{}  ({:.1} Msim-cycles/s, {:.1} Muops/s)",
        s.report(),
        cycles as f64 / s.median.as_secs_f64() / 1e6,
        uops as f64 / s.median.as_secs_f64() / 1e6
    );
}
