//! Bench: the hot paths of each layer, for the performance pass
//! (EXPERIMENTS.md §Perf).
//!
//! * mdb: cached registry lookups, cold vs warm form resolution
//!   (`FormIndex`);
//! * L3 simulator: simulated Mcycles/s and µops/s on the heaviest
//!   kernels, plus `DecodedKernel` reuse and 1-iteration latency;
//! * L3 analyzer: kernels analyzed per second (warm path);
//! * L1/L2 solver: batched artifact executions per second (PJRT) vs the
//!   pure-rust reference;
//! * coordinator / api: end-to-end requests per second, serial vs the
//!   pooled batch path.
//!
//! Results are also written as machine-readable JSON
//! (`BENCH_hotpath.json`, override with `OSACA_BENCH_JSON`) so the perf
//! trajectory is tracked across PRs. `OSACA_BENCH_SMOKE=1` shrinks the
//! iteration counts for the `./ci.sh --bench-smoke` gate.
//!
//! Run: `cargo bench --bench hotpath`

use std::sync::Arc;

use osaca::analyzer::analyze;
use osaca::baseline::encode;
use osaca::benchlib::{bench, BenchJson, Stats};
use osaca::coordinator::Coordinator;
use osaca::mdb;
use osaca::runtime::{solve_cpu, EncodedKernel, PortSolver, BATCH};
use osaca::sim::{run_decoded, simulate, DecodedKernel, SimConfig};
use osaca::workloads;

/// Per-layer repetition counts, shrunken under `OSACA_BENCH_SMOKE`.
struct Scale {
    lookups: usize,
    sim_cfg: SimConfig,
    n_reqs: usize,
    warm_small: usize,
    samp_small: usize,
    warm_big: usize,
    samp_big: usize,
}

fn scale() -> Scale {
    if std::env::var("OSACA_BENCH_SMOKE").is_ok() {
        Scale {
            lookups: 10_000,
            sim_cfg: SimConfig { iterations: 200, warmup: 40 },
            n_reqs: 32,
            warm_small: 1,
            samp_small: 3,
            warm_big: 1,
            samp_big: 2,
        }
    } else {
        Scale {
            lookups: 1_000_000,
            sim_cfg: SimConfig { iterations: 4000, warmup: 400 },
            n_reqs: 128,
            warm_small: 2,
            samp_small: 10,
            warm_big: 1,
            samp_big: 8,
        }
    }
}

fn main() {
    let sc = scale();
    let mut json = BenchJson::new();
    let skl = mdb::by_name_shared("skl").unwrap();
    let zen = mdb::by_name_shared("zen").unwrap();

    // ---- machine-model registry ---------------------------------------
    // Built-in models are parsed once per process and served from the
    // Arc cache; assert that a pile of lookups does not re-parse.
    println!("--- mdb registry ---");
    let parses_before = mdb::builtin_parse_count();
    let s = bench("mdb/by_name_shared/lookups", 2, 10, || {
        for _ in 0..sc.lookups {
            std::hint::black_box(mdb::by_name_shared("skl"));
        }
    });
    let lookup_rate = sc.lookups as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} lookups/s)", s.report(), lookup_rate);
    json.record(&s, &[("lookups_per_s", lookup_rate)]);
    assert_eq!(
        mdb::builtin_parse_count(),
        parses_before,
        "cached machine-model lookups must not re-parse the embedded .mdb text"
    );

    // ---- dynamic registry: lazy load ----------------------------------
    // A zoo-imported model pays exactly one parse on first resolution,
    // then serves from the same eviction-free Arc cache as the
    // built-ins — the serving hot path must never re-parse.
    {
        let xml = include_str!("../tests/fixtures/uops_trimmed.xml");
        osaca::zoo::import_and_register(xml, "clx").expect("import clx fixture");
        let dyn_parses_before = mdb::registry_parse_count();
        mdb::by_name_shared("clx").expect("registered model resolves");
        let dyn_parses_warm = mdb::registry_parse_count();
        assert_eq!(dyn_parses_warm, dyn_parses_before + 1, "first lookup parses once");
        let s = bench("mdb/registry_lazy_load", 2, 10, || {
            for _ in 0..sc.lookups {
                std::hint::black_box(mdb::by_name_shared("clx"));
            }
        });
        let rate = sc.lookups as f64 / s.median.as_secs_f64();
        println!("{}  ({:.0} lookups/s)", s.report(), rate);
        json.record(&s, &[("lookups_per_s", rate)]);
        assert_eq!(
            mdb::registry_parse_count(),
            dyn_parses_warm,
            "warm dynamic-registry lookups must not re-parse registered .mdb text"
        );
    }

    // ---- form resolution: cold vs warm --------------------------------
    // Cold = a fresh per-model FormIndex every run (every synthesized
    // form is re-derived); warm = the shared cached model (every resolve
    // is an interned cache hit).
    println!("--- mdb form resolution ---");
    let kernels: Vec<_> = workloads::all().iter().map(|w| w.kernel()).collect();
    let n_resolves: usize = kernels
        .iter()
        .map(|k| k.instructions.iter().filter(|i| !i.is_branch()).count())
        .sum();
    let resolve_all = |m: &mdb::MachineModel| {
        for k in &kernels {
            for ins in k.instructions.iter().filter(|i| !i.is_branch()) {
                std::hint::black_box(m.resolve(ins).unwrap());
            }
        }
    };
    let s = bench("resolve/cold/skl", sc.warm_small, sc.samp_small, || {
        let fresh = mdb::skylake(); // clone => fresh resolution cache
        resolve_all(&fresh);
    });
    let cold_rate = n_resolves as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} resolutions/s)", s.report(), cold_rate);
    json.record(&s, &[("resolutions_per_s", cold_rate)]);

    resolve_all(&skl); // warm the shared cache explicitly
    let misses_before = skl.resolution_miss_count();
    let s = bench("resolve/warm/skl", sc.warm_small, sc.samp_small, || {
        resolve_all(&skl);
    });
    let warm_rate = n_resolves as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} resolutions/s)", s.report(), warm_rate);
    json.record(&s, &[("resolutions_per_s", warm_rate)]);
    assert_eq!(
        skl.resolution_miss_count(),
        misses_before,
        "warm resolution must perform zero fresh syntheses"
    );

    // ---- L3 simulator -------------------------------------------------
    println!("--- L3 simulator ---");
    for (arch, m) in [("skl", &skl), ("zen", &zen)] {
        let w = workloads::find("pi", arch, "-O3").unwrap();
        let k = w.kernel();
        let mut total_cycles = 0u64;
        let mut uops = 0u64;
        let s = bench(&format!("sim/pi-o3/{arch}"), sc.warm_small, sc.samp_small, || {
            let meas = simulate(&k, m, sc.sim_cfg).unwrap();
            total_cycles = meas.total_cycles;
            uops = meas.counters.uops_executed;
        });
        report_sim(&s, total_cycles, uops, &mut json);
    }
    {
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let k = w.kernel();
        let mut total_cycles = 0u64;
        let mut uops = 0u64;
        let s = bench("sim/triad-o3/skl", sc.warm_small, sc.samp_small, || {
            let meas = simulate(&k, &skl, sc.sim_cfg).unwrap();
            total_cycles = meas.total_cycles;
            uops = meas.counters.uops_executed;
        });
        report_sim(&s, total_cycles, uops, &mut json);
    }
    {
        // DecodedKernel reuse: decode once, run many times.
        let w = workloads::find("pi", "skl", "-O3").unwrap();
        let k = w.kernel();
        let dk = DecodedKernel::new(&k, &skl).unwrap();
        let mut total_cycles = 0u64;
        let mut uops = 0u64;
        let s = bench("sim/pi-o3-reuse/skl", sc.warm_small, sc.samp_small, || {
            let meas = run_decoded(&dk, &skl, sc.sim_cfg);
            total_cycles = meas.total_cycles;
            uops = meas.counters.uops_executed;
        });
        report_sim(&s, total_cycles, uops, &mut json);
        // Single-iteration latency: what one interactive SIMULATE pass
        // costs once decode is amortized away.
        let one = SimConfig { iterations: 1, warmup: 0 };
        let s = bench("sim/pi-o3-1iter/skl", sc.warm_small, sc.samp_small, || {
            std::hint::black_box(run_decoded(&dk, &skl, one));
        });
        let rate = 1.0 / s.median.as_secs_f64();
        println!("{}  ({:.0} runs/s)", s.report(), rate);
        json.record(&s, &[("runs_per_s", rate)]);
    }
    {
        // Cache-aware mode, L1-resident: the memory-model plumbing is on
        // (LSQ tracking, per-load miss checks) but no load ever misses,
        // so this prices the pure overhead of the opt-in path against
        // the sim/triad-o3 runs above.
        use osaca::sim::{analyze_memory, derive_footprint, run_decoded_mem, MemModel, MemSimPlan};
        let w = workloads::find("triad-strided", "any", "-O3").unwrap();
        let k = w.kernel();
        let dk = DecodedKernel::new(&k, &skl).unwrap();
        let model = MemModel::build(&skl, "ws=16K").unwrap();
        let fp = derive_footprint(&k, &dk.iter, model.line_bytes());
        let analysis = analyze_memory(&model, &fp, sc.sim_cfg.iterations as u64);
        let plan = MemSimPlan::new(&model, &analysis, &fp);
        let mut total_cycles = 0u64;
        let mut uops = 0u64;
        let s = bench("sim/mem_l1_resident", sc.warm_small, sc.samp_small, || {
            let meas = run_decoded_mem(&dk, &skl, sc.sim_cfg, Some(&plan));
            total_cycles = meas.total_cycles;
            uops = meas.counters.uops_executed;
        });
        report_sim(&s, total_cycles, uops, &mut json);
    }
    {
        // The whole working-set sweep (the `mem-sweep` subcommand and
        // the `--mem-smoke` CI leg): one infinite-L1 analysis plus one
        // cache-aware analysis per pinned size.
        use osaca::report::experiments::{mem_sweep, MEM_SWEEP_SIZES};
        let mut points = 0usize;
        let s = bench("sim/mem_sweep", sc.warm_small, sc.samp_small, || {
            let rows = mem_sweep("triad-strided", "any", "-O3", "skl", &MEM_SWEEP_SIZES).unwrap();
            points = rows.len();
        });
        let rate = points as f64 / s.median.as_secs_f64();
        println!("{}  ({:.0} points/s)", s.report(), rate);
        json.record(&s, &[("points_per_s", rate)]);
    }

    // ---- L3 analyzer ---------------------------------------------------
    println!("--- L3 analyzer ---");
    let s = bench("analyze/all-workloads/skl", 3, 20, || {
        for k in &kernels {
            analyze(k, &skl).unwrap();
        }
    });
    let analyze_rate = kernels.len() as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} kernels/s)", s.report(), analyze_rate);
    json.record(&s, &[("kernels_per_s", analyze_rate)]);

    // ---- L1/L2 solver ---------------------------------------------------
    println!("--- L1/L2 port solver ---");
    let encs: Vec<EncodedKernel> = kernels.iter().map(|k| encode(k, &skl).unwrap()).collect();
    let batch: Vec<EncodedKernel> = encs.iter().cycle().take(BATCH).cloned().collect();
    let s = bench("solve/cpu-reference/batch8", 3, 20, || {
        solve_cpu(&batch, 32);
    });
    let rate = BATCH as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} kernels/s)", s.report(), rate);
    json.record(&s, &[("kernels_per_s", rate)]);
    match PortSolver::load_default() {
        Ok(solver) => {
            let s = bench("solve/pjrt-artifact/batch8", 3, 20, || {
                solver.solve(&batch).unwrap();
            });
            let rate = BATCH as f64 / s.median.as_secs_f64();
            println!("{}  ({:.0} kernels/s)", s.report(), rate);
            json.record(&s, &[("kernels_per_s", rate)]);
        }
        Err(e) => println!("solve/pjrt-artifact: SKIPPED ({e})"),
    }

    // ---- coordinator ----------------------------------------------------
    println!("--- coordinator ---");
    let coord = Arc::new(Coordinator::auto());
    let n = sc.n_reqs;
    let s = bench(&format!("coordinator/end-to-end/{n}-reqs"), sc.warm_big, sc.samp_big, || {
        let mut handles = Vec::new();
        for i in 0..n {
            let coord = coord.clone();
            handles.push(std::thread::spawn(move || {
                let ws = workloads::all();
                let w = ws[i % ws.len()];
                let m = if i % 2 == 0 { mdb::skylake() } else { mdb::zen() };
                coord.analyze_kernel(&w.kernel(), &m).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    let rate = n as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} req/s)", s.report(), rate);
    json.record(&s, &[("req_per_s", rate)]);
    println!(
        "coordinator stats: {} batches, avg batch {:.2}",
        coord.stats.batches.load(std::sync::atomic::Ordering::Relaxed),
        coord.stats.avg_batch_size()
    );

    // ---- api batch path -------------------------------------------------
    // Serial analyze() loop vs the pooled analyze_batch fast path (one
    // submission, direct B=8 slot mapping, scoped worker pool for the
    // analytic passes).
    use osaca::api::{Engine, Passes};
    let engine = Engine::cpu_only();
    let ws = workloads::all();
    let reqs: Vec<_> = (0..n)
        .map(|i| {
            let w = ws[i % ws.len()];
            Engine::request(&w.name())
                .arch(if i % 2 == 0 { "skl" } else { "zen" })
                .source(w.source)
                .passes(Passes::ANALYTIC)
                .unroll(w.unroll)
        })
        .collect();
    let s = bench(&format!("api/analyze_serial/{n}-reqs"), sc.warm_big, sc.samp_big, || {
        for req in &reqs {
            engine.analyze(req).unwrap();
        }
    });
    let rate = n as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} req/s)", s.report(), rate);
    json.record(&s, &[("req_per_s", rate)]);
    let s = bench(&format!("api/analyze_batch/{n}-reqs"), sc.warm_big, sc.samp_big, || {
        let results = engine.analyze_batch(&reqs);
        assert!(results.iter().all(|r| r.is_ok()));
    });
    let rate = n as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} req/s)", s.report(), rate);
    json.record(&s, &[("req_per_s", rate)]);
    println!(
        "engine stats: {} batches, avg batch {:.2}",
        engine.stats().batches.load(std::sync::atomic::Ordering::Relaxed),
        engine.stats().avg_batch_size()
    );

    // ---- corpus scorecard path ------------------------------------------
    // Whole-file basic blocks through load→batch→aggregate: the
    // end-to-end `osaca corpus` rate, minus file IO.
    println!("--- corpus ---");
    {
        use osaca::corpus::{self, CorpusBlock, CorpusOptions};
        let n_blocks = if std::env::var("OSACA_BENCH_SMOKE").is_ok() { 32 } else { 128 };
        let blocks: Vec<CorpusBlock> = (0..n_blocks)
            .map(|i| {
                let w = ws[i % ws.len()];
                CorpusBlock { name: format!("block_{i:04}.s"), source: w.source.to_string() }
            })
            .collect();
        let opts = CorpusOptions::default();
        let mut errors = 0;
        let s = bench("corpus/blocks_per_s", sc.warm_big, sc.samp_big, || {
            let card = corpus::score_blocks(&engine, &blocks, &opts);
            errors = card.errors();
        });
        assert_eq!(errors, 0, "workload-derived corpus blocks must all score");
        let rate = n_blocks as f64 / s.median.as_secs_f64();
        println!("{}  ({:.0} blocks/s)", s.report(), rate);
        json.record(&s, &[("blocks_per_s", rate)]);
    }

    // ---- executor: steal overhead ---------------------------------------
    // Pure scheduling cost of the unified pool: no-op jobs all homed to
    // one worker of a 2-worker pool, so a large share of them cross the
    // cross-worker steal path instead of the home fast path.
    println!("--- executor ---");
    {
        use osaca::exec::{ExecConfig, Executor, Job};
        use std::sync::mpsc;
        let exec: Executor<()> = Executor::new(
            ExecConfig {
                workers: 2,
                queue_depth: 1024,
                name: "osaca-bench-exec".to_string(),
                ..Default::default()
            },
            |_worker| (),
        );
        let jobs = if std::env::var("OSACA_BENCH_SMOKE").is_ok() { 2_000 } else { 20_000 };
        let s = bench("exec/steal_overhead", 2, 10, || {
            let (tx, rx) = mpsc::channel();
            for _ in 0..jobs {
                let tx = tx.clone();
                exec.submit(
                    Some(0),
                    Job::new(move |_ctx| {
                        tx.send(()).unwrap();
                    }),
                )
                .unwrap_or_else(|_| panic!("submit to bench pool"));
            }
            drop(tx);
            assert_eq!(rx.iter().count(), jobs, "bench pool lost jobs");
        });
        let rate = jobs as f64 / s.median.as_secs_f64();
        let steals = exec.stats().steals.load(std::sync::atomic::Ordering::Relaxed);
        println!("{}  ({:.0} jobs/s, {steals} steals)", s.report(), rate);
        json.record(&s, &[("jobs_per_s", rate)]);
        exec.close();
        exec.join();
    }

    // ---- report construction + emitters ---------------------------------
    // What one serving-path response costs after the passes are done:
    // assembling the Prediction bound decomposition and emitting the
    // versioned JSON. Frontend bound on, so the decomposition carries
    // every analytic bound kind.
    println!("--- report emitters ---");
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let report = engine
        .analyze(
            &Engine::request(&w.name())
                .arch("skl")
                .source(w.source)
                .passes(Passes::ANALYTIC)
                .frontend_bound(true)
                .unroll(w.unroll),
        )
        .unwrap();
    const EMITS: usize = 1000;
    let s = bench("report/prediction_build", 2, 10, || {
        for _ in 0..EMITS {
            std::hint::black_box(report.prediction());
        }
    });
    let rate = EMITS as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} predictions/s)", s.report(), rate);
    json.record(&s, &[("predictions_per_s", rate)]);
    let s = bench("report/json_emit", 2, 10, || {
        for _ in 0..EMITS {
            std::hint::black_box(report.to_json());
        }
    });
    let rate = EMITS as f64 / s.median.as_secs_f64();
    println!("{}  ({:.0} emits/s)", s.report(), rate);
    json.record(&s, &[("json_emits_per_s", rate)]);

    // ---- serve: the persistent TCP service ------------------------------
    // End-to-end wire cost per request: frame parse, shard queue, memo
    // lookup, render, socket round-trip. Four persistent connections
    // cycling the workload mix — after the first round almost every
    // request is a memo hit, which is the steady state a long-lived
    // service actually runs in. Latency percentiles are recorded as
    // *inverse* rates (1/p50, 1/p99) so the bench baseline gate keeps
    // its below-baseline-is-regression direction for every shared key.
    println!("--- serve ---");
    {
        use osaca::report::emit::json_string;
        use osaca::serve::{ServeConfig, Server};
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpStream;

        let server = Server::bind(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            backend: osaca::api::Backend::Cpu,
            ..ServeConfig::default()
        })
        .expect("bind serve bench server");
        let addr = server.local_addr();
        let frames: Vec<String> = (0..n)
            .map(|i| {
                let w = ws[i % ws.len()];
                let arch = if i % 2 == 0 { "skl" } else { "zen" };
                format!(
                    "{{\"op\":\"analyze\",\"name\":{},\"arch\":\"{arch}\",\"source\":{},\
                     \"passes\":[\"analytic\"],\"unroll\":{}}}",
                    json_string(&w.name()),
                    json_string(w.source),
                    w.unroll
                )
            })
            .collect();
        let clients = 4.min(n.max(1));
        let per_client = (n / clients).max(1);
        let mut latencies: Vec<f64> = Vec::new();
        let s = bench("serve/req_s", sc.warm_big, sc.samp_big, || {
            latencies.clear();
            let handles: Vec<_> = frames
                .chunks(per_client)
                .map(|chunk| {
                    let chunk = chunk.to_vec();
                    std::thread::spawn(move || {
                        let stream = TcpStream::connect(addr).expect("connect");
                        let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                        let mut stream = stream;
                        let mut lats = Vec::with_capacity(chunk.len());
                        let mut line = String::new();
                        for f in &chunk {
                            let t0 = std::time::Instant::now();
                            stream.write_all(f.as_bytes()).expect("send frame");
                            stream.write_all(b"\n").expect("send newline");
                            line.clear();
                            reader.read_line(&mut line).expect("read response");
                            lats.push(t0.elapsed().as_secs_f64());
                            assert!(line.contains("\"status\":\"ok\""), "serve error: {line}");
                        }
                        lats
                    })
                })
                .collect();
            for h in handles {
                latencies.extend(h.join().expect("client thread"));
            }
        });
        latencies.sort_by(f64::total_cmp);
        let p50 = latencies[latencies.len() / 2];
        let p99 = latencies[(latencies.len() * 99 / 100).min(latencies.len() - 1)];
        let rate = n as f64 / s.median.as_secs_f64();
        println!(
            "{}  ({:.0} req/s; latency p50 {:.1}µs p99 {:.1}µs)",
            s.report(),
            rate,
            p50 * 1e6,
            p99 * 1e6
        );
        json.record(
            &s,
            &[
                ("req_per_s", rate),
                ("p50_req_per_s", 1.0 / p50),
                ("p99_req_per_s", 1.0 / p99),
            ],
        );
        server.shutdown();
        server.join();
    }

    // ---- serve: load-shed rejection latency -----------------------------
    // How fast a saturated server says "no": a 1×1 deployment pinned at
    // its full gauge (one job in flight, one queued) sheds every fresh
    // analyze — admission ladder, memo probe, overloaded frame, socket
    // round-trip — without touching a worker. Rejections must stay
    // cheap or shedding defeats its purpose.
    {
        use osaca::report::emit::json_string;
        use osaca::serve::{ServeConfig, Server};
        use std::io::{BufRead, BufReader, Write};
        use std::net::TcpStream;

        let server = Server::bind(ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            backend: osaca::api::Backend::Cpu,
            shards: 1,
            queue_depth: 1,
            test_ops: true,
            ..ServeConfig::default()
        })
        .expect("bind shed bench server");
        let addr = server.local_addr();
        let connect = || {
            let stream = TcpStream::connect(addr).expect("connect");
            let reader = BufReader::new(stream.try_clone().expect("clone"));
            (stream, reader)
        };
        let round_trip = |stream: &mut TcpStream, reader: &mut BufReader<TcpStream>, f: &str| {
            stream.write_all(f.as_bytes()).expect("send frame");
            stream.write_all(b"\n").expect("send newline");
            let mut line = String::new();
            reader.read_line(&mut line).expect("read response");
            line
        };
        // Saturate: one sleep in flight plus one queued is the full
        // gauge of a 1×1 deployment — the auto shed threshold. The
        // sleeps outlive the measured phase by a wide margin.
        let (mut blocker, mut blocker_r) = connect();
        blocker.write_all(b"{\"op\":\"sleep\",\"ms\":2500}\n").expect("blocker");
        std::thread::sleep(std::time::Duration::from_millis(50));
        let (mut filler, mut filler_r) = connect();
        filler.write_all(b"{\"op\":\"sleep\",\"ms\":10}\n").expect("filler");
        let (mut c, mut r) = connect();
        loop {
            let stats = round_trip(&mut c, &mut r, "{\"op\":\"stats\"}");
            if stats.contains("\"shedding\":true") {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        // A request that is never memoized (it is always shed before it
        // could be analyzed), so every round trip is a fresh rejection.
        let w = workloads::find("triad", "skl", "-O3").unwrap();
        let miss = format!(
            "{{\"op\":\"analyze\",\"name\":{},\"arch\":\"skl\",\"source\":{},\
             \"passes\":[\"analytic\"],\"unroll\":{}}}",
            json_string(&w.name()),
            json_string(w.source),
            w.unroll
        );
        let rejects = if std::env::var("OSACA_BENCH_SMOKE").is_ok() { 100 } else { 500 };
        let s = bench("serve/shed_latency", 1, 2, || {
            for _ in 0..rejects {
                let line = round_trip(&mut c, &mut r, &miss);
                let shed = line.contains("\"status\":\"overloaded\"")
                    && line.contains("\"shedding\":true");
                assert!(shed, "expected a shed rejection: {line}");
            }
        });
        let rate = rejects as f64 / s.median.as_secs_f64();
        println!("{}  ({:.0} rejects/s)", s.report(), rate);
        json.record(&s, &[("rejects_per_s", rate)]);
        // Drain the sleepers before shutdown so join() is immediate.
        let mut line = String::new();
        blocker_r.read_line(&mut line).expect("blocker reply");
        line.clear();
        filler_r.read_line(&mut line).expect("filler reply");
        server.shutdown();
        server.join();
    }

    // ---- machine-readable results ---------------------------------------
    let path =
        std::env::var("OSACA_BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    match json.write(&path) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn report_sim(s: &Stats, cycles: u64, uops: u64, json: &mut BenchJson) {
    let mcy = cycles as f64 / s.median.as_secs_f64() / 1e6;
    let mu = uops as f64 / s.median.as_secs_f64() / 1e6;
    println!("{}  ({:.1} Msim-cycles/s, {:.1} Muops/s)", s.report(), mcy, mu);
    json.record(s, &[("msim_cycles_per_s", mcy), ("muops_per_s", mu)]);
}
