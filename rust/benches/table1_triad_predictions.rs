//! Bench: regenerate paper Table I (triad throughput analyses) and time
//! the predictor paths that produce it.
//!
//! Run: `cargo bench --bench table1_triad_predictions`

use osaca::analyzer::analyze;
use osaca::benchlib::{bench, print_table, SAMPLES, WARMUP};
use osaca::coordinator::Coordinator;
use osaca::mdb;
use osaca::report::experiments::{render_table1, table1};
use osaca::workloads;

fn main() {
    let coord = Coordinator::auto();

    // The table itself.
    let rows = table1(&coord).expect("table1");
    print_table(
        "Table I: OSACA and IACA-like throughput analyses (cy per assembly iteration)",
        &["compiled for", "flag", "unroll", "OSACA Zen", "OSACA SKL", "IACA-like SKL"],
        &render_table1(&rows),
    );

    // Timings of the underlying predictor paths.
    let skl = mdb::skylake();
    let zen = mdb::zen();
    let w = workloads::find("triad", "skl", "-O3").unwrap();
    let k = w.kernel();

    let s = bench("analyze/osaca/triad-skl-o3 (skl)", WARMUP, SAMPLES, || {
        analyze(&k, &skl).unwrap();
    });
    println!("{}", s.report());
    let s = bench("analyze/osaca/triad-skl-o3 (zen, 256-split)", WARMUP, SAMPLES, || {
        analyze(&k, &zen).unwrap();
    });
    println!("{}", s.report());
    let s = bench("predict/balanced-baseline (through coordinator)", WARMUP, SAMPLES, || {
        coord.analyze_kernel(&k, &skl).unwrap();
    });
    println!("{}", s.report());
    let s = bench("table1/full-regeneration", 1, 5, || {
        table1(&coord).unwrap();
    });
    println!("{}", s.report());
}
