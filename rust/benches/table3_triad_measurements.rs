//! Bench: regenerate paper Table III (triad measurements on the
//! simulator substrate vs predictions) and time the simulator.
//!
//! Run: `cargo bench --bench table3_triad_measurements`

use osaca::benchlib::{bench, print_table, SAMPLES, WARMUP};
use osaca::coordinator::Coordinator;
use osaca::mdb;
use osaca::report::experiments::{render_table3, table3};
use osaca::sim::{simulate, SimConfig};
use osaca::workloads;

fn main() {
    let coord = Coordinator::auto();
    let cfg = SimConfig::default();
    let rows = table3(&coord, cfg).expect("table3");
    print_table(
        "Table III: triad measured (simulator @1.8 GHz) vs predictions",
        &[
            "executed on",
            "compiled for",
            "flag",
            "unroll",
            "MFLOP/s",
            "Mit/s",
            "measured cy/it",
            "OSACA cy/it",
            "IACA-like cy/it",
        ],
        &render_table3(&rows),
    );

    // Simulator throughput: simulated cycles per wall-second.
    for (arch, family, flag) in
        [("skl", "triad", "-O3"), ("zen", "triad", "-O3"), ("skl", "pi", "-O1")]
    {
        let w = workloads::find(family, arch, flag).unwrap();
        let m = mdb::by_name(arch).unwrap();
        let k = w.kernel();
        let cfg = SimConfig { iterations: 2000, warmup: 200 };
        let mut cycles = 0u64;
        let s = bench(&format!("sim/{}-{}-{}", family, arch, flag), WARMUP, SAMPLES, || {
            let m = simulate(&k, &m, cfg).unwrap();
            cycles = m.total_cycles;
        });
        println!(
            "{}  ({:.1} Msim-cycles/s)",
            s.report(),
            cycles as f64 / s.median.as_secs_f64() / 1e6
        );
    }
    let s = bench("table3/full-regeneration", 1, 3, || {
        table3(&coord, SimConfig { iterations: 400, warmup: 100 }).unwrap();
    });
    println!("{}", s.report());
}
