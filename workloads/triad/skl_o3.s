# Schoenauer triad, gcc -O3 -march=skylake: 256-bit AVX2 + FMA,
# 4 source iterations per assembly iteration (paper Table II listing).
	xorl	%ecx, %ecx
	xorq	%rax, %rax
.L10:
	vmovapd	(%r15,%rax), %ymm0
	vmovapd	(%r12,%rax), %ymm3
	addl	$1, %ecx
	vfmadd132pd	0(%r13,%rax), %ymm3, %ymm0
	vmovapd	%ymm0, (%r14,%rax)
	addq	$32, %rax
	cmpl	%ecx, %r10d
	ja	.L10
