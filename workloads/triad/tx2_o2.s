// STREAM triad a[i] = b[i] + s*c[i], compiled for AArch64/ThunderX2
// at -O2 with 128-bit ASIMD vectorization: one assembly iteration
// covers 16 bytes = 2 doubles (unroll 2).
//
// x7 = b, x8 = c, x9 = a, x4 = byte offset, x5 = remaining elements,
// v2.2d = broadcast scalar s (loop-invariant).
//
// OSACA/IACA markers (AArch64 flavor: mov x1 + nop encoding bytes).
	mov	x1, #111
	.byte	213,3,32,31
.L4:
	ldr	q0, [x7, x4]
	ldr	q1, [x8, x4]
	fmla	v0.2d, v1.2d, v2.2d
	str	q0, [x9, x4]
	add	x4, x4, #16
	subs	x5, x5, #2
	b.ne	.L4
	mov	x1, #222
	.byte	213,3,32,31
	ret
