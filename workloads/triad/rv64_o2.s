# STREAM triad a[i] = b[i] + s*c[i], compiled for RV64GC at -O2:
# scalar double, one source iteration per assembly iteration (RV64GC
# has no vector extension, and the single offset(base) addressing mode
# forces one pointer bump per stream).
#
# a5 = &b[i], a4 = &c[i], a3 = &a[i], a6 = &b[n] (loop bound),
# fa0 = scalar s (loop-invariant).
#
# Designed bottleneck: the single LS pipe carries 2 loads + 1 store
# AGU = 3.0 cy/iter for the analyzer — but the dual-issue frontend
# (8 slots / 2-wide = 4.0 cy/iter) is the real limit the uniform-split
# port model cannot see; tests/riscv_rv64.rs pins both numbers.
#
# OSACA/IACA markers (RISC-V flavor: li t0 + canonical-nop bytes).
	li	t0, 111
	.byte	19,0,0,0
.L3:
	fld	fa4, 0(a5)
	fld	fa3, 0(a4)
	fmadd.d	fa4, fa3, fa0, fa4
	fsd	fa4, 0(a3)
	addi	a5, a5, 8
	addi	a4, a4, 8
	addi	a3, a3, 8
	bne	a5, a6, .L3
	li	t0, 222
	.byte	19,0,0,0
	ret
