# Schoenauer triad a[i] = b[i] + c[i] * d[i], gcc -O1 style:
# scalar SSE, separate loads for c[i] and d[i], common index in %rax.
# Identical code is produced for both compile targets.
	xorl	%eax, %eax
.L3:
	vmovsd	(%rcx,%rax,8), %xmm0
	vmovsd	(%rdx,%rax,8), %xmm1
	vmulsd	%xmm1, %xmm0, %xmm0
	vaddsd	(%rsi,%rax,8), %xmm0, %xmm0
	vmovsd	%xmm0, (%rdi,%rax,8)
	addq	$1, %rax
	cmpq	%rbp, %rax
	jne	.L3
