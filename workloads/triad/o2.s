# Schoenauer triad a[i] = b[i] + c[i] * d[i], gcc -O2 style:
# scalar SSE with memory-operand arithmetic (mulsd/addsd fold the
# loads). Identical code is produced for both compile targets.
	xorl	%eax, %eax
.L3:
	vmovsd	(%rcx,%rax,8), %xmm0
	vmulsd	(%rdx,%rax,8), %xmm0, %xmm0
	vaddsd	(%rsi,%rax,8), %xmm0, %xmm0
	vmovsd	%xmm0, (%rdi,%rax,8)
	addq	$1, %rax
	cmpq	%rbp, %rax
	jne	.L3
