# Schoenauer triad, gcc -O3 -march=znver1: 128-bit SSE/AVX + FMA,
# 2 source iterations per assembly iteration (paper Table IV listing).
	xorl	%esi, %esi
	xorq	%rax, %rax
.L10:
	vmovaps	0(%r13,%rax), %xmm0
	vmovaps	(%r15,%rax), %xmm3
	incl	%esi
	vfmadd132pd	(%r14,%rax), %xmm3, %xmm0
	vmovaps	%xmm0, (%r12,%rax)
	addq	$16, %rax
	cmpl	%esi, %ebx
	ja	.L10
