// Pi by numerical integration (paper Listing 2) compiled for
// AArch64/ThunderX2 at -O1: scalar, one source iteration per assembly
// iteration, 5 FLOP/iter.
//
// w4 = i, w5 = n, d4 = 0.5, d5 = dx, d6 = 1.0, d7 = 4.0 (invariant),
// d8 = running sum. The sum recurrence (fadd, 6 cy) and the
// non-pipelined divide (DV busy 16 cy) are the candidate bottlenecks;
// the divider wins.
	mov	x1, #111
	.byte	213,3,32,31
.L2:
	scvtf	d0, w4
	fadd	d0, d0, d4
	fmul	d0, d0, d5
	fmul	d1, d0, d0
	fadd	d1, d1, d6
	fdiv	d2, d7, d1
	fadd	d8, d8, d2
	add	w4, w4, #1
	cmp	w4, w5
	b.ne	.L2
	mov	x1, #222
	.byte	213,3,32,31
	ret
