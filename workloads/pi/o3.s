# pi integration, gcc -O3 style: 4-wide vectorized (int32 counter
# vector converted to double) and 2-way unrolled with two accumulators
# -> 8 source iterations per assembly iteration. Bound by the two
# 256-bit divides on the divider pipe (paper Table VI: 0DV = 16).
# Identical code is produced for both compile targets.
	xorl	%eax, %eax
.L6:
	vcvtdq2pd	%xmm6, %ymm0
	vpaddd	%xmm7, %xmm6, %xmm6
	vfmadd132pd	%ymm8, %ymm9, %ymm0
	vmulpd	%ymm0, %ymm0, %ymm1
	vaddpd	%ymm10, %ymm1, %ymm1
	vdivpd	%ymm1, %ymm11, %ymm1
	vaddpd	%ymm1, %ymm2, %ymm2
	vcvtdq2pd	%xmm6, %ymm3
	vpaddd	%xmm7, %xmm6, %xmm6
	vfmadd132pd	%ymm8, %ymm9, %ymm3
	vmulpd	%ymm3, %ymm3, %ymm4
	vaddpd	%ymm10, %ymm4, %ymm4
	vdivpd	%ymm4, %ymm12, %ymm4
	vaddpd	%ymm4, %ymm5, %ymm5
	addl	$1, %eax
	cmpl	%edx, %eax
	jne	.L6
