# Pi by numerical integration (paper Listing 2) compiled for RV64GC at
# -O1: scalar, one source iteration per assembly iteration, 5 FLOP/iter.
#
# a4 = i, a5 = n, fa2 = 0.5, fa3 = dx, fa1 = 1.0, fa0 = 4.0
# (loop-invariant), fs0 = running sum. There is no separate compare:
# the bne at the bottom is RISC-V's compare-and-branch, executing a
# real µ-op on the B pipe.
#
# The sum recurrence (fadd.d, 5 cy) and the non-pipelined divide (DV
# busy 12 cy) are the candidate bottlenecks; the divider wins.
	li	t0, 111
	.byte	19,0,0,0
.L2:
	fcvt.d.w	fa5, a4
	fadd.d	fa5, fa5, fa2
	fmul.d	fa5, fa5, fa3
	fmul.d	fa4, fa5, fa5
	fadd.d	fa4, fa4, fa1
	fdiv.d	fa4, fa0, fa4
	fadd.d	fs0, fs0, fa4
	addiw	a4, a4, 1
	bne	a4, a5, .L2
	li	t0, 222
	.byte	19,0,0,0
	ret
