# pi integration sum += 4/(1+x*x), x = (i+0.5)*delta, gcc -O1 style:
# the running sum lives on the stack, so every iteration round-trips
# through a store-to-load forward (the paper's §III-B anomaly).
# Identical code is produced for both compile targets.
	xorl	%eax, %eax
.L4:
	pxor	%xmm0, %xmm0
	vcvtsi2sd	%eax, %xmm0, %xmm0
	vaddsd	%xmm4, %xmm0, %xmm0
	vmulsd	%xmm5, %xmm0, %xmm0
	vmulsd	%xmm0, %xmm0, %xmm3
	vaddsd	%xmm6, %xmm3, %xmm3
	vdivsd	%xmm3, %xmm7, %xmm3
	vaddsd	8(%rsp), %xmm3, %xmm1
	vmovsd	%xmm1, 8(%rsp)
	addl	$1, %eax
	cmpl	%edx, %eax
	jne	.L4
