# pi integration, gcc -O2 style: sum and i stay in registers, FMA
# contracts 1 + x*x, and the compiler emits the pxor zeroing idiom to
# break the cvtsi2sd false dependency plus a cmp+jne pair that
# macro-fuses on real hardware — the two "shortcuts" OSACA charges but
# IACA and the silicon do not (paper Table VII: 4.25 vs 4.00).
# Identical code is produced for both compile targets.
	xorl	%eax, %eax
.L5:
	pxor	%xmm0, %xmm0
	vcvtsi2sd	%eax, %xmm0, %xmm0
	vaddsd	%xmm4, %xmm0, %xmm0
	vmulsd	%xmm5, %xmm0, %xmm0
	vfmadd132sd	%xmm0, %xmm6, %xmm0
	vdivsd	%xmm0, %xmm7, %xmm0
	vaddsd	%xmm0, %xmm2, %xmm2
	addl	$1, %eax
	cmpl	%edx, %eax
	jne	.L5
