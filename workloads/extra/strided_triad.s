# Schoenauer triad with a 128-byte stride: same four-stream ymm body
# as the skl -O3 triad but the pointer bump skips three vectors per
# iteration, so each assembly iteration opens two fresh cachelines per
# stream (4 x 128 B = 512 B = 8 lines/iter). L1-resident it is still
# the 2.0 cy port-bound kernel; blow L1 and the infinite-L1 model is
# provably wrong — exactly the fixture the opt-in memory model pins.
	xorl	%ecx, %ecx
	xorq	%rax, %rax
.L20:
	vmovapd	(%r15,%rax), %ymm0
	vmovapd	(%r12,%rax), %ymm3
	addl	$1, %ecx
	vfmadd132pd	0(%r13,%rax), %ymm3, %ymm0
	vmovapd	%ymm0, (%r14,%rax)
	addq	$128, %rax
	cmpl	%ecx, %r10d
	ja	.L20
