# Scalar sum reduction s += a[i]: a single loop-carried FP-add chain,
# the latency-bound counterpoint to the throughput-bound kernels
# (4 cy/iter on Skylake, 3 on Zen — the FP add latency).
	vxorpd	%xmm0, %xmm0, %xmm0
	xorl	%eax, %eax
	xorq	%rbp, %rbp
.L60:
	vaddsd	(%rsi,%rax,8), %xmm0, %xmm0
	addq	$1, %rax
	cmpq	%rbp, %rax
	jne	.L60
