# daxpy y[i] = a*x[i] + y[i], 256-bit in-place update: the fma folds
# the x load, the y store targets the address the load just read —
# same-iteration, so no cross-iteration forwarding is triggered.
	xorq	%rax, %rax
	xorq	%rbp, %rbp
.L50:
	vmovapd	(%rsi,%rax), %ymm1
	vfmadd231pd	(%rdi,%rax), %ymm0, %ymm1
	vmovapd	%ymm1, (%rsi,%rax)
	addq	$32, %rax
	cmpq	%rbp, %rax
	jne	.L50
