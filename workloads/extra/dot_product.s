# Dot product s += a[i]*b[i], 256-bit, 2x unrolled with two
# accumulators (8 source iterations per assembly iteration).
	vxorpd	%xmm0, %xmm0, %xmm0
	vxorpd	%xmm1, %xmm1, %xmm1
	xorq	%rax, %rax
.L30:
	vmovapd	(%rsi,%rax), %ymm2
	vfmadd231pd	(%rdi,%rax), %ymm2, %ymm0
	vmovapd	32(%rsi,%rax), %ymm3
	vfmadd231pd	32(%rdi,%rax), %ymm3, %ymm1
	addq	$64, %rax
	cmpq	%rbp, %rax
	jne	.L30
