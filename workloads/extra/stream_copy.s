# STREAM copy b[i] = a[i], 256-bit, 2x unrolled (8 doubles per
# assembly iteration): pure load/store pressure, zero FLOPs.
	xorq	%rax, %rax
	xorq	%rbp, %rbp
.L40:
	vmovapd	(%rsi,%rax), %ymm0
	vmovapd	%ymm0, (%rdi,%rax)
	vmovapd	32(%rsi,%rax), %ymm1
	vmovapd	%ymm1, 32(%rdi,%rax)
	addq	$64, %rax
	cmpq	%rbp, %rax
	jne	.L40
