# Legacy-SSE Schoenauer triad (pre-VEX two-operand forms), 128-bit,
# 2 source iterations per assembly iteration. Exercises the non-VEX
# database entries and read-modify-write destination semantics.
	xorq	%rax, %rax
	xorq	%rbp, %rbp
.L20:
	movaps	(%rcx,%rax), %xmm0
	movaps	(%rdx,%rax), %xmm1
	mulpd	%xmm1, %xmm0
	addpd	(%rsi,%rax), %xmm0
	movaps	%xmm0, (%rdi,%rax)
	addq	$16, %rax
	cmpq	%rbp, %rax
	jne	.L20
